//! Deterministic search + certification of irreducible polynomials over
//! `GF(q)` (Rabin's test). Used when constructing `GR(p^e, d)` moduli and
//! tower moduli `h(y)` with `h̄` irreducible over the residue field.

use super::gfp::{
    fq_poly_gcd, fq_poly_powmod, fq_poly_sub, fq_poly_trim, Gfq, GfqElem,
};

/// Prime factorization by trial division (arguments are tiny: extension
/// degrees).
pub fn prime_factors(mut n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 2u64;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// Rabin irreducibility test for a monic polynomial `h` of degree `m ≥ 1`
/// over `GF(q)` (given as coefficient vector of `GfqElem`s, length `m+1`).
///
/// `h` is irreducible iff `y^(q^m) ≡ y (mod h)` and for every prime `r | m`,
/// `gcd(y^(q^(m/r)) − y, h) = 1`.
pub fn is_irreducible(field: &Gfq, h: &[GfqElem]) -> bool {
    let m = h.len() - 1;
    assert!(m >= 1, "degree must be >= 1");
    assert!(!field.is_zero(&h[m]), "polynomial must have nonzero leading term");
    if m == 1 {
        return true; // linear polynomials are always irreducible
    }
    let q = field.size();
    let y: Vec<GfqElem> = vec![field.zero(), field.one()];

    // frob^k(y) = y^(q^k) mod h, computed by k successive q-th powers.
    let frob_iter = |k: usize| -> Vec<GfqElem> {
        let mut t = y.clone();
        for _ in 0..k {
            t = fq_poly_powmod(field, &t, q, h);
        }
        t
    };

    // y^(q^m) ≡ y (mod h)?
    let ym = frob_iter(m);
    if fq_poly_trim(field, fq_poly_sub(field, &ym, &y)) != Vec::<GfqElem>::new() {
        return false;
    }
    // gcd checks for maximal proper sub-degrees.
    for r in prime_factors(m as u64) {
        let k = m / r as usize;
        let yk = frob_iter(k);
        let diff = fq_poly_sub(field, &yk, &y);
        let g = fq_poly_gcd(field, &diff, h);
        if g.len() != 1 {
            return false; // nontrivial gcd ⇒ reducible
        }
    }
    true
}

/// Find the lexicographically-first monic irreducible polynomial of degree
/// `m` over `GF(q)`. Deterministic, so every run of the system builds the
/// same ring. Density of irreducibles is ≈ 1/m, so the scan is instant for
/// the degrees we use (≤ 64).
pub fn find_irreducible(field: &Gfq, m: usize) -> Vec<GfqElem> {
    assert!(m >= 1);
    let q = field.size();
    // Enumerate the m lower coefficients as base-q digits of a counter.
    let total = q.checked_pow(m as u32);
    let mut idx: u128 = 0;
    loop {
        if let Some(t) = total {
            assert!(idx < t, "no irreducible polynomial found (impossible)");
        }
        let mut h: Vec<GfqElem> = Vec::with_capacity(m + 1);
        let mut v = idx;
        for _ in 0..m {
            h.push(field.element_from_index(v % q));
            v /= q;
        }
        h.push(field.one()); // monic
        // Quick screen: constant term must be nonzero (else divisible by y).
        if !field.is_zero(&h[0]) && is_irreducible(field, &h) {
            return h;
        }
        idx += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::gfp::fq_poly_mul;

    fn gf2() -> Gfq {
        Gfq::new(2, vec![0, 1]) // GF(2) as GF(2)[x]/(x)
    }

    #[test]
    fn factors() {
        assert_eq!(prime_factors(12), vec![2, 3]);
        assert_eq!(prime_factors(1), Vec::<u64>::new());
        assert_eq!(prime_factors(64), vec![2]);
        assert_eq!(prime_factors(7), vec![7]);
    }

    #[test]
    fn known_irreducibles_gf2() {
        let f = gf2();
        let one = f.one();
        let zero = f.zero();
        // x^2 + x + 1 irreducible over GF(2)
        assert!(is_irreducible(&f, &[one.clone(), one.clone(), one.clone()]));
        // x^2 + 1 = (x+1)^2 reducible
        assert!(!is_irreducible(&f, &[one.clone(), zero.clone(), one.clone()]));
        // x^3 + x + 1 irreducible
        assert!(is_irreducible(
            &f,
            &[one.clone(), one.clone(), zero.clone(), one.clone()]
        ));
        // x^4 + x + 1 irreducible
        assert!(is_irreducible(
            &f,
            &[one.clone(), one.clone(), zero.clone(), zero.clone(), one.clone()]
        ));
        // x^4 + x^2 + 1 = (x^2+x+1)^2 reducible
        assert!(!is_irreducible(
            &f,
            &[one.clone(), zero.clone(), one.clone(), zero.clone(), one.clone()]
        ));
    }

    #[test]
    fn product_is_reducible() {
        let f = gf2();
        let one = f.one();
        let zero = f.zero();
        let a = vec![one.clone(), one.clone(), one.clone()]; // x^2+x+1
        let b = vec![one.clone(), one.clone(), zero.clone(), one.clone()]; // x^3+x+1
        let prod = fq_poly_mul(&f, &a, &b);
        assert_eq!(prod.len(), 6);
        assert!(!is_irreducible(&f, &prod));
    }

    #[test]
    fn find_degree_1_through_8_gf2() {
        let f = gf2();
        for m in 1..=8 {
            let h = find_irreducible(&f, m);
            assert_eq!(h.len(), m + 1);
            assert!(is_irreducible(&f, &h), "degree {m}");
        }
    }

    #[test]
    fn find_over_gf4() {
        // GF(4) = GF(2)[x]/(x^2+x+1); find an irreducible quadratic and cubic
        // over GF(4) — needed for towers over GR(p^e, 2).
        let f = Gfq::new(2, vec![1, 1, 1]);
        for m in [2usize, 3, 4] {
            let h = find_irreducible(&f, m);
            assert!(is_irreducible(&f, &h), "degree {m} over GF(4)");
        }
    }

    #[test]
    fn find_over_gf3() {
        let f = Gfq::new(3, vec![0, 1]);
        for m in [2usize, 3, 5] {
            let h = find_irreducible(&f, m);
            assert!(is_irreducible(&f, &h));
        }
    }
}
