//! The algebraic substrate: integer residue rings `Z_{p^e}`, Galois rings
//! `GR(p^e, d)`, tower extensions `GR(p^e, d·m)`, residue-field helpers,
//! irreducible-polynomial search, dense polynomials, fast multipoint
//! evaluation / interpolation (Lemma II.1), and matrices over any ring —
//! both the element-generic AoS [`matrix::Matrix`] and the flat plane-major
//! [`plane::PlaneMatrix`] that the coding/coordinator layers use for
//! everything on the encode → wire → worker → decode path.
//!
//! Everything the paper's schemes need algebraically lives here; the `codes`
//! and `rmfe` modules are generic over the [`traits::Ring`] and
//! [`plane::PlaneRing`] traits. Base-ring slice kernels (axpy / scale /
//! matmul-accumulate) route through the runtime-dispatched SIMD backend
//! table in [`arch`] via the `Ring` slice hooks — see `GR_CDMM_SIMD`.

pub mod arch;
pub mod traits;
pub mod zq;
pub mod gfp;
pub mod irreducible;
pub mod galois;
pub mod extension;
pub mod poly;
pub mod eval;
pub mod matrix;
pub mod plane;

pub use traits::Ring;
pub use zq::Zq;
pub use galois::GaloisRing;
pub use extension::Extension;
pub use matrix::Matrix;
pub use plane::{PlaneMatrix, PlaneRing};
