//! `Zq` — the integer residue ring `Z_{p^e} = GR(p^e, 1)`.
//!
//! Two representations:
//! * `Mask` — `p = 2`, any `e ≤ 64`: arithmetic is wrap-around `u64` masked to
//!   `e` bits. For `e = 64` (the paper's main experimental ring `Z_{2^64}`)
//!   this is native machine arithmetic — additions and multiplications compile
//!   to single instructions, exactly the "directly compatible with CPU words"
//!   motivation of the paper.
//! * `Mod` — odd prime `p`, `p^e < 2^63`: reduction via `u128` products on
//!   the scalar path; the bulk slice kernels go through [`Montgomery`]
//!   multiplication instead (no per-element division).
//!
//! **Slice kernels.** `Zq` overrides the [`Ring`] slice hooks
//! (`slice_axpy_assign` / `slice_scale_assign` / `slice_mat_mul_acc`) to
//! run through the runtime-dispatched kernel table in
//! [`crate::ring::arch`] — reference scalar loops, autovectorizer-friendly
//! generic loops, or per-ISA SIMD, selected by `GR_CDMM_SIMD` / CPU
//! detection. All backends are bit-identical (canonical residues, so the
//! result of a modular sum is order- and algorithm-independent); the
//! scalar entry points (`add`/`mul`/`mul_add_assign`) stay the reference
//! implementations and double as the oracle.

use super::arch;
use super::traits::Ring;
use crate::util::rng::Rng64;

/// Precomputed Montgomery-multiplication constants for an odd modulus
/// `q < 2^63` — what lets the optimized slice kernels drop the per-element
/// `u128 %` (PR 7 / the paper's "directly compatible with hardware"
/// pitch extended to odd `p^e`).
///
/// With `R = 2^64`: `mont_mul(a, b) = a·b·R⁻¹ mod q` costs three 64×64→128
/// multiplies and one conditional subtract. Converting one operand to
/// Montgomery form first (`a·R mod q`, via [`Montgomery::to_mont`]) makes
/// the product plain `a·b mod q` — so a slice kernel converts its scalar
/// once and pays zero divisions per element. All outputs are canonical
/// (`< q`), which is why the Montgomery path is bit-identical to the
/// reference `%` path.
///
/// The residue-field machinery in [`crate::ring::gfp`] stays on plain `%`
/// arithmetic — it only runs at scheme-construction time (see the note
/// there).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Montgomery {
    /// The odd modulus `q = p^e < 2^63`.
    pub q: u64,
    /// `−q⁻¹ mod 2^64`.
    neg_q_inv: u64,
    /// `R² mod q` where `R = 2^64` (the to-Montgomery conversion factor).
    r2: u64,
}

impl Montgomery {
    /// Build the constants for odd `q < 2^63`.
    pub fn new(q: u64) -> Montgomery {
        assert!(q & 1 == 1, "Montgomery needs an odd modulus");
        assert!(q < (1 << 63), "q must be < 2^63");
        // q⁻¹ mod 2^64 by Newton iteration: x ← x(2 − qx) doubles the
        // number of correct low bits; x₀ = q is correct mod 8 (odd² ≡ 1),
        // so five steps reach 2^64.
        let mut inv = q;
        for _ in 0..5 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(q.wrapping_mul(inv)));
        }
        debug_assert_eq!(q.wrapping_mul(inv), 1);
        let r = (u64::MAX % q) + 1; // 2^64 mod q (q ∤ 2^64, so no wrap to q)
        let r = if r == q { 0 } else { r };
        let r2 = ((r as u128 * r as u128) % q as u128) as u64;
        Montgomery { q, neg_q_inv: inv.wrapping_neg(), r2 }
    }

    /// Montgomery product `a·b·R⁻¹ mod q`, canonical. With `a` in Montgomery
    /// form (`a = x·R mod q`) this is the plain product `x·b mod q`.
    #[inline]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        let t = a as u128 * b as u128;
        let m = (t as u64).wrapping_mul(self.neg_q_inv);
        // t + m·q < 2^126 + 2^127 — no u128 overflow; the low 64 bits
        // cancel by construction of m, and u = (t + m·q)/2^64 < 2q.
        let u = ((t + m as u128 * self.q as u128) >> 64) as u64;
        if u >= self.q {
            u - self.q
        } else {
            u
        }
    }

    /// Convert into Montgomery form: `a·R mod q`.
    #[inline]
    pub fn to_mont(&self, a: u64) -> u64 {
        self.mul(a, self.r2)
    }

    /// Canonical modular add of two canonical residues.
    #[inline]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        let s = a + b; // both < q < 2^63, no overflow
        if s >= self.q {
            s - self.q
        } else {
            s
        }
    }
}

/// Internal representation of the modulus.
#[derive(Clone, Debug, PartialEq)]
enum Repr {
    /// `q = 2^e`; the mask is `2^e − 1` (all-ones for `e = 64`).
    Mask { mask: u64 },
    /// General `q = p^e < 2^63`, with the Montgomery constants the
    /// dispatched slice kernels use (derived from `q`, so `PartialEq`
    /// on `q` alone would be equivalent).
    Mod { q: u64, mont: Montgomery },
}

/// The ring `Z_{p^e}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Zq {
    p: u64,
    e: u32,
    repr: Repr,
}

impl Zq {
    /// `Z_{2^e}` for `1 ≤ e ≤ 64`.
    pub fn z2e(e: u32) -> Zq {
        assert!((1..=64).contains(&e), "e must be in 1..=64");
        let mask = if e == 64 { u64::MAX } else { (1u64 << e) - 1 };
        Zq { p: 2, e, repr: Repr::Mask { mask } }
    }

    /// `Z_{p^e}` for odd prime `p` with `p^e < 2^63`.
    pub fn new(p: u64, e: u32) -> Zq {
        if p == 2 {
            return Zq::z2e(e);
        }
        assert!(is_small_prime(p), "p = {p} is not prime");
        assert!(e >= 1);
        let mut q: u64 = 1;
        for _ in 0..e {
            q = q.checked_mul(p).expect("p^e overflows u64");
        }
        assert!(q < (1 << 63), "p^e must be < 2^63 for the Mod representation");
        Zq { p, e, repr: Repr::Mod { q, mont: Montgomery::new(q) } }
    }

    /// The modulus `q = p^e` as `u128`.
    pub fn q(&self) -> u128 {
        match self.repr {
            Repr::Mask { mask } => mask as u128 + 1,
            Repr::Mod { q, .. } => q as u128,
        }
    }

    /// Canonical reduction of an arbitrary u64 into the ring.
    #[inline]
    pub fn reduce(&self, x: u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => x & mask,
            Repr::Mod { q, .. } => x % q,
        }
    }

    /// Lift of a signed integer.
    pub fn from_i64(&self, x: i64) -> u64 {
        if x >= 0 {
            self.reduce(x as u64)
        } else {
            self.neg(&self.reduce((-x) as u64))
        }
    }
}

/// Trial-division primality (moduli are small user inputs, not hot-path data).
pub fn is_small_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3u64;
    while d.saturating_mul(d) <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

impl Ring for Zq {
    type Elem = u64;

    #[inline]
    fn p(&self) -> u64 {
        self.p
    }
    #[inline]
    fn e(&self) -> u32 {
        self.e
    }
    #[inline]
    fn degree(&self) -> usize {
        1
    }

    #[inline]
    fn zero(&self) -> u64 {
        0
    }
    #[inline]
    fn one(&self) -> u64 {
        1
    }

    #[inline]
    fn add(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_add(*b) & mask,
            Repr::Mod { q, .. } => {
                let s = a + b; // both < q < 2^63, no overflow
                if s >= q {
                    s - q
                } else {
                    s
                }
            }
        }
    }

    #[inline]
    fn sub(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_sub(*b) & mask,
            Repr::Mod { q, .. } => {
                if a >= b {
                    a - b
                } else {
                    a + q - b
                }
            }
        }
    }

    #[inline]
    fn neg(&self, a: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_neg() & mask,
            Repr::Mod { q, .. } => {
                if *a == 0 {
                    0
                } else {
                    q - a
                }
            }
        }
    }

    #[inline]
    fn mul(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_mul(*b) & mask,
            Repr::Mod { q, .. } => ((*a as u128 * *b as u128) % q as u128) as u64,
        }
    }

    #[inline]
    fn add_assign(&self, a: &mut u64, b: &u64) {
        *a = self.add(a, b);
    }

    #[inline]
    fn mul_add_assign(&self, acc: &mut u64, a: &u64, b: &u64) {
        match self.repr {
            // Defer the mask to read time? No — keep canonical. Single fused op.
            Repr::Mask { mask } => *acc = acc.wrapping_add(a.wrapping_mul(*b)) & mask,
            Repr::Mod { q, .. } => {
                let t = ((*a as u128 * *b as u128) % q as u128) as u64;
                *acc = self.add(acc, &t);
            }
        }
    }

    #[inline]
    fn is_zero(&self, a: &u64) -> bool {
        *a == 0
    }

    #[inline]
    fn is_unit(&self, a: &u64) -> bool {
        a % self.p != 0
    }

    fn exceptional_points(&self, n: usize) -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(
            (n as u128) <= self.p as u128,
            "Z_{{{}^{}}} has only {} exceptional points, {} requested (Section II-B: \
             extend the ring via GR(p^e, m) — see Extension)",
            self.p,
            self.e,
            self.p,
            n
        );
        Ok((0..n as u64).collect())
    }

    #[inline]
    fn elem_bytes(&self) -> usize {
        8
    }

    fn write_elem(&self, a: &u64, out: &mut Vec<u8>) {
        out.extend_from_slice(&a.to_le_bytes());
    }

    fn read_elem(&self, buf: &[u8], pos: &mut usize) -> u64 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[*pos..*pos + 8]);
        *pos += 8;
        u64::from_le_bytes(b)
    }

    /// Bulk override: a `u64` slice serializes as one little-endian block
    /// copy instead of a per-element loop (the plane-major wire hot path —
    /// a whole share plane is a single `memcpy`).
    fn write_slice(&self, xs: &[u64], out: &mut Vec<u8>) {
        if cfg!(target_endian = "little") {
            // SAFETY: reinterpreting an initialized `u64` slice as bytes is
            // always valid (`u8` has alignment 1, no padding, length
            // `len·8`); on little-endian targets the byte order is exactly
            // the canonical `to_le_bytes` wire format.
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), xs.len() * 8) };
            out.extend_from_slice(bytes);
        } else {
            for x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Bulk override of [`Ring::read_slice`]: one block copy on
    /// little-endian targets. Caller has validated the length (see the
    /// trait docs); the explicit slice below re-checks it regardless.
    fn read_slice(&self, buf: &[u8], pos: &mut usize, count: usize) -> Vec<u64> {
        let end = *pos + count * 8;
        let src = &buf[*pos..end];
        *pos = end;
        if cfg!(target_endian = "little") {
            let mut out = vec![0u64; count];
            // SAFETY: `out` owns `count·8` writable bytes; `src` holds
            // exactly `count·8` initialized bytes; the regions cannot
            // overlap (fresh allocation). Little-endian byte order matches
            // the wire format.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr(),
                    out.as_mut_ptr().cast::<u8>(),
                    count * 8,
                );
            }
            out
        } else {
            src.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().expect("chunks of 8")))
                .collect()
        }
    }

    /// Dispatch override: route the slice axpy through the runtime-selected
    /// kernel table ([`crate::ring::arch`]) — every backend is bit-identical
    /// to the reference scalar loop (property-tested).
    fn slice_axpy_assign(&self, acc: &mut [u64], s: &u64, x: &[u64]) {
        debug_assert_eq!(acc.len(), x.len());
        let k = arch::active_kernels();
        match &self.repr {
            Repr::Mask { mask } => (k.axpy_mask)(acc, *s, x, *mask),
            Repr::Mod { mont, .. } => (k.axpy_mod)(acc, *s, x, mont),
        }
    }

    /// Dispatch override: in-place slice scale through the kernel table.
    fn slice_scale_assign(&self, xs: &mut [u64], s: &u64) {
        let k = arch::active_kernels();
        match &self.repr {
            Repr::Mask { mask } => (k.scale_mask)(xs, *s, *mask),
            Repr::Mod { mont, .. } => (k.scale_mod)(xs, *s, mont),
        }
    }

    /// Dispatch override: the dense `c += a·b` slice kernel — the worker
    /// hot path (every plane-major matmul bottoms out here, `m²` times per
    /// extension matmul) — through the kernel table.
    fn slice_mat_mul_acc(
        &self,
        c: &mut [u64],
        a: &[u64],
        b: &[u64],
        ar: usize,
        ac: usize,
        bc: usize,
    ) {
        debug_assert_eq!(a.len(), ar * ac);
        debug_assert_eq!(b.len(), ac * bc);
        debug_assert_eq!(c.len(), ar * bc);
        let k = arch::active_kernels();
        match &self.repr {
            Repr::Mask { mask } => (k.matmul_mask)(c, a, b, ar, ac, bc, *mask),
            Repr::Mod { mont, .. } => (k.matmul_mod)(c, a, b, ar, ac, bc, mont),
        }
    }

    fn random(&self, rng: &mut Rng64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => rng.next_u64() & mask,
            Repr::Mod { q, .. } => rng.below(q),
        }
    }

    fn name(&self) -> String {
        format!("Z_{}^{}", self.p, self.e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::traits::is_exceptional_sequence;

    #[test]
    fn z2_64_wraps() {
        let r = Zq::z2e(64);
        assert_eq!(r.add(&u64::MAX, &1), 0);
        assert_eq!(r.mul(&(1u64 << 63), &2), 0);
        assert_eq!(r.sub(&0, &1), u64::MAX);
    }

    #[test]
    fn z2_32_masks() {
        let r = Zq::z2e(32);
        assert_eq!(r.add(&(u32::MAX as u64), &1), 0);
        assert_eq!(r.mul(&(1u64 << 31), &2), 0);
        assert_eq!(r.q(), 1u128 << 32);
    }

    #[test]
    fn odd_modulus_arithmetic() {
        let r = Zq::new(3, 5); // 243
        assert_eq!(r.q(), 243);
        assert_eq!(r.add(&200, &100), 57);
        assert_eq!(r.sub(&5, &10), 238);
        assert_eq!(r.mul(&100, &100), 100 * 100 % 243);
        assert_eq!(r.neg(&0), 0);
        assert_eq!(r.neg(&1), 242);
    }

    #[test]
    fn units_and_inverses_z2e() {
        let r = Zq::z2e(64);
        for a in [1u64, 3, 5, 0xDEAD_BEEF_1234_5677, u64::MAX] {
            assert!(r.is_unit(&a), "{a} should be a unit");
            let inv = r.inv(&a).unwrap();
            assert_eq!(r.mul(&a, &inv), 1, "a={a}");
        }
        for a in [0u64, 2, 4, 1 << 20] {
            assert!(!r.is_unit(&a));
            assert!(r.inv(&a).is_none());
        }
    }

    #[test]
    fn units_and_inverses_z3e() {
        let r = Zq::new(3, 4); // 81
        for a in 0..81u64 {
            if a % 3 != 0 {
                let inv = r.inv(&a).unwrap();
                assert_eq!(r.mul(&a, &inv), 1, "a={a}");
            } else {
                assert!(r.inv(&a).is_none());
            }
        }
    }

    #[test]
    fn field_case_e1() {
        // Z_p with e = 1 is GF(p); inverse = Fermat only, no Hensel steps.
        let r = Zq::new(7, 1);
        for a in 1..7u64 {
            assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), 1);
        }
    }

    #[test]
    fn exceptional_points_z2() {
        let r = Zq::z2e(64);
        let pts = r.exceptional_points(2).unwrap();
        assert_eq!(pts, vec![0, 1]);
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(3).is_err(), "Z_2^e has only 2");
    }

    #[test]
    fn exceptional_points_z7() {
        let r = Zq::new(7, 2);
        let pts = r.exceptional_points(7).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(8).is_err());
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let r = Zq::z2e(16);
        let a = 12345u64 & 0xFFFF;
        let mut acc = 1u64;
        for n in 0..20u32 {
            assert_eq!(r.pow_u128(&a, n as u128), acc);
            acc = r.mul(&acc, &a);
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let r = Zq::z2e(64);
        let mut buf = Vec::new();
        let vals = [0u64, 1, u64::MAX, 0x0123_4567_89AB_CDEF];
        for v in &vals {
            r.write_elem(v, &mut buf);
        }
        assert_eq!(buf.len(), vals.len() * r.elem_bytes());
        let mut pos = 0;
        for v in &vals {
            assert_eq!(r.read_elem(&buf, &mut pos), *v);
        }
    }

    #[test]
    fn bulk_slice_io_matches_per_element() {
        let r = Zq::z2e(64);
        let vals = [0u64, 1, u64::MAX, 0x0123_4567_89AB_CDEF, 42];
        let mut per_elem = Vec::new();
        for v in &vals {
            r.write_elem(v, &mut per_elem);
        }
        let mut bulk = vec![0xAAu8; 3]; // pre-existing bytes must be kept
        r.write_slice(&vals, &mut bulk);
        assert_eq!(&bulk[3..], per_elem.as_slice());
        let mut pos = 3;
        assert_eq!(r.read_slice(&bulk, &mut pos, vals.len()), vals);
        assert_eq!(pos, bulk.len());
        // zero-length slice is a no-op
        let mut pos = 0;
        assert_eq!(r.read_slice(&[], &mut pos, 0), Vec::<u64>::new());
        assert_eq!(pos, 0);
    }

    #[test]
    fn from_i64_signed() {
        let r = Zq::z2e(8);
        assert_eq!(r.from_i64(-1), 255);
        assert_eq!(r.from_i64(300), 44);
    }

    #[test]
    fn primality_helper() {
        assert!(is_small_prime(2));
        assert!(is_small_prime(3));
        assert!(is_small_prime(65537));
        assert!(!is_small_prime(1));
        assert!(!is_small_prime(91));
    }

    #[test]
    fn dot_and_sum() {
        let r = Zq::z2e(64);
        let xs = [1u64, 2, 3];
        let ys = [4u64, 5, 6];
        assert_eq!(r.dot(&xs, &ys), 32);
        assert_eq!(r.sum(&xs), 6);
    }

    #[test]
    fn montgomery_matches_reference_mul() {
        // every odd modulus family the schemes touch: tiny, prime power,
        // near the 2^63 representation limit
        for q in [3u64, 243, 2401, 65537, (1u64 << 62) - 1, 4611686018427387847] {
            let m = Montgomery::new(q);
            let mut rng = Rng64::seeded(q ^ 0xDEAD);
            let mut cases = vec![(0u64, 0u64), (0, 1), (1, q - 1), (q - 1, q - 1)];
            for _ in 0..200 {
                cases.push((rng.below(q), rng.below(q)));
            }
            for (a, b) in cases {
                let want = ((a as u128 * b as u128) % q as u128) as u64;
                assert_eq!(m.mul(m.to_mont(a), b), want, "q={q} a={a} b={b}");
                // to_mont/mont-domain roundtrip: a·R·R⁻¹ = a
                assert_eq!(m.mul(m.to_mont(a), 1), a, "q={q} a={a}");
            }
        }
    }

    #[test]
    fn montgomery_add_is_canonical_modular_add() {
        let q = 1000003u64; // prime
        let m = Montgomery::new(q);
        assert_eq!(m.add(q - 1, 1), 0);
        assert_eq!(m.add(q - 1, q - 1), q - 2);
        assert_eq!(m.add(0, 5), 5);
    }

    #[test]
    fn slice_hooks_match_scalar_ops() {
        // The dispatched slice kernels must agree with the per-element
        // scalar path on both representations (whatever backend is active).
        for r in [Zq::z2e(64), Zq::z2e(17), Zq::new(3, 5), Zq::new(65537, 1)] {
            let mut rng = Rng64::seeded(99);
            let s = r.random(&mut rng);
            let x: Vec<u64> = (0..37).map(|_| r.random(&mut rng)).collect();
            let acc0: Vec<u64> = (0..37).map(|_| r.random(&mut rng)).collect();
            let mut want = acc0.clone();
            for (a, b) in want.iter_mut().zip(&x) {
                r.mul_add_assign(a, &s, b);
            }
            let mut got = acc0.clone();
            r.slice_axpy_assign(&mut got, &s, &x);
            assert_eq!(got, want, "axpy {}", r.name());

            let mut want = x.clone();
            for v in want.iter_mut() {
                *v = r.mul(v, &s);
            }
            let mut got = x.clone();
            r.slice_scale_assign(&mut got, &s);
            assert_eq!(got, want, "scale {}", r.name());
        }
    }
}
