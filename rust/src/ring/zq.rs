//! `Zq` — the integer residue ring `Z_{p^e} = GR(p^e, 1)`.
//!
//! Two representations:
//! * `Mask` — `p = 2`, any `e ≤ 64`: arithmetic is wrap-around `u64` masked to
//!   `e` bits. For `e = 64` (the paper's main experimental ring `Z_{2^64}`)
//!   this is native machine arithmetic — additions and multiplications compile
//!   to single instructions, exactly the "directly compatible with CPU words"
//!   motivation of the paper.
//! * `Mod` — odd prime `p`, `p^e < 2^63`: reduction via `u128` products.

use super::traits::Ring;
use crate::util::rng::Rng64;

/// Internal representation of the modulus.
#[derive(Clone, Debug, PartialEq)]
enum Repr {
    /// `q = 2^e`; the mask is `2^e − 1` (all-ones for `e = 64`).
    Mask { mask: u64 },
    /// General `q = p^e < 2^63`.
    Mod { q: u64 },
}

/// The ring `Z_{p^e}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Zq {
    p: u64,
    e: u32,
    repr: Repr,
}

impl Zq {
    /// `Z_{2^e}` for `1 ≤ e ≤ 64`.
    pub fn z2e(e: u32) -> Zq {
        assert!((1..=64).contains(&e), "e must be in 1..=64");
        let mask = if e == 64 { u64::MAX } else { (1u64 << e) - 1 };
        Zq { p: 2, e, repr: Repr::Mask { mask } }
    }

    /// `Z_{p^e}` for odd prime `p` with `p^e < 2^63`.
    pub fn new(p: u64, e: u32) -> Zq {
        if p == 2 {
            return Zq::z2e(e);
        }
        assert!(is_small_prime(p), "p = {p} is not prime");
        assert!(e >= 1);
        let mut q: u64 = 1;
        for _ in 0..e {
            q = q.checked_mul(p).expect("p^e overflows u64");
        }
        assert!(q < (1 << 63), "p^e must be < 2^63 for the Mod representation");
        Zq { p, e, repr: Repr::Mod { q } }
    }

    /// The modulus `q = p^e` as `u128`.
    pub fn q(&self) -> u128 {
        match self.repr {
            Repr::Mask { mask } => mask as u128 + 1,
            Repr::Mod { q } => q as u128,
        }
    }

    /// Canonical reduction of an arbitrary u64 into the ring.
    #[inline]
    pub fn reduce(&self, x: u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => x & mask,
            Repr::Mod { q } => x % q,
        }
    }

    /// Lift of a signed integer.
    pub fn from_i64(&self, x: i64) -> u64 {
        if x >= 0 {
            self.reduce(x as u64)
        } else {
            self.neg(&self.reduce((-x) as u64))
        }
    }
}

/// Trial-division primality (moduli are small user inputs, not hot-path data).
pub fn is_small_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3u64;
    while d.saturating_mul(d) <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

impl Ring for Zq {
    type Elem = u64;

    #[inline]
    fn p(&self) -> u64 {
        self.p
    }
    #[inline]
    fn e(&self) -> u32 {
        self.e
    }
    #[inline]
    fn degree(&self) -> usize {
        1
    }

    #[inline]
    fn zero(&self) -> u64 {
        0
    }
    #[inline]
    fn one(&self) -> u64 {
        1
    }

    #[inline]
    fn add(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_add(*b) & mask,
            Repr::Mod { q } => {
                let s = a + b; // both < q < 2^63, no overflow
                if s >= q {
                    s - q
                } else {
                    s
                }
            }
        }
    }

    #[inline]
    fn sub(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_sub(*b) & mask,
            Repr::Mod { q } => {
                if a >= b {
                    a - b
                } else {
                    a + q - b
                }
            }
        }
    }

    #[inline]
    fn neg(&self, a: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_neg() & mask,
            Repr::Mod { q } => {
                if *a == 0 {
                    0
                } else {
                    q - a
                }
            }
        }
    }

    #[inline]
    fn mul(&self, a: &u64, b: &u64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => a.wrapping_mul(*b) & mask,
            Repr::Mod { q } => ((*a as u128 * *b as u128) % q as u128) as u64,
        }
    }

    #[inline]
    fn add_assign(&self, a: &mut u64, b: &u64) {
        *a = self.add(a, b);
    }

    #[inline]
    fn mul_add_assign(&self, acc: &mut u64, a: &u64, b: &u64) {
        match self.repr {
            // Defer the mask to read time? No — keep canonical. Single fused op.
            Repr::Mask { mask } => *acc = acc.wrapping_add(a.wrapping_mul(*b)) & mask,
            Repr::Mod { q } => {
                let t = ((*a as u128 * *b as u128) % q as u128) as u64;
                *acc = self.add(acc, &t);
            }
        }
    }

    #[inline]
    fn is_zero(&self, a: &u64) -> bool {
        *a == 0
    }

    #[inline]
    fn is_unit(&self, a: &u64) -> bool {
        a % self.p != 0
    }

    fn exceptional_points(&self, n: usize) -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(
            (n as u128) <= self.p as u128,
            "Z_{{{}^{}}} has only {} exceptional points, {} requested (Section II-B: \
             extend the ring via GR(p^e, m) — see Extension)",
            self.p,
            self.e,
            self.p,
            n
        );
        Ok((0..n as u64).collect())
    }

    #[inline]
    fn elem_bytes(&self) -> usize {
        8
    }

    fn write_elem(&self, a: &u64, out: &mut Vec<u8>) {
        out.extend_from_slice(&a.to_le_bytes());
    }

    fn read_elem(&self, buf: &[u8], pos: &mut usize) -> u64 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[*pos..*pos + 8]);
        *pos += 8;
        u64::from_le_bytes(b)
    }

    /// Bulk override: a `u64` slice serializes as one little-endian block
    /// copy instead of a per-element loop (the plane-major wire hot path —
    /// a whole share plane is a single `memcpy`).
    fn write_slice(&self, xs: &[u64], out: &mut Vec<u8>) {
        if cfg!(target_endian = "little") {
            // SAFETY: reinterpreting an initialized `u64` slice as bytes is
            // always valid (`u8` has alignment 1, no padding, length
            // `len·8`); on little-endian targets the byte order is exactly
            // the canonical `to_le_bytes` wire format.
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), xs.len() * 8) };
            out.extend_from_slice(bytes);
        } else {
            for x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Bulk override of [`Ring::read_slice`]: one block copy on
    /// little-endian targets. Caller has validated the length (see the
    /// trait docs); the explicit slice below re-checks it regardless.
    fn read_slice(&self, buf: &[u8], pos: &mut usize, count: usize) -> Vec<u64> {
        let end = *pos + count * 8;
        let src = &buf[*pos..end];
        *pos = end;
        if cfg!(target_endian = "little") {
            let mut out = vec![0u64; count];
            // SAFETY: `out` owns `count·8` writable bytes; `src` holds
            // exactly `count·8` initialized bytes; the regions cannot
            // overlap (fresh allocation). Little-endian byte order matches
            // the wire format.
            unsafe {
                std::ptr::copy_nonoverlapping(src.as_ptr(), out.as_mut_ptr().cast::<u8>(), count * 8);
            }
            out
        } else {
            src.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().expect("chunks of 8")))
                .collect()
        }
    }

    fn random(&self, rng: &mut Rng64) -> u64 {
        match self.repr {
            Repr::Mask { mask } => rng.next_u64() & mask,
            Repr::Mod { q } => rng.below(q),
        }
    }

    fn name(&self) -> String {
        format!("Z_{}^{}", self.p, self.e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::traits::is_exceptional_sequence;

    #[test]
    fn z2_64_wraps() {
        let r = Zq::z2e(64);
        assert_eq!(r.add(&u64::MAX, &1), 0);
        assert_eq!(r.mul(&(1u64 << 63), &2), 0);
        assert_eq!(r.sub(&0, &1), u64::MAX);
    }

    #[test]
    fn z2_32_masks() {
        let r = Zq::z2e(32);
        assert_eq!(r.add(&(u32::MAX as u64), &1), 0);
        assert_eq!(r.mul(&(1u64 << 31), &2), 0);
        assert_eq!(r.q(), 1u128 << 32);
    }

    #[test]
    fn odd_modulus_arithmetic() {
        let r = Zq::new(3, 5); // 243
        assert_eq!(r.q(), 243);
        assert_eq!(r.add(&200, &100), 57);
        assert_eq!(r.sub(&5, &10), 238);
        assert_eq!(r.mul(&100, &100), 100 * 100 % 243);
        assert_eq!(r.neg(&0), 0);
        assert_eq!(r.neg(&1), 242);
    }

    #[test]
    fn units_and_inverses_z2e() {
        let r = Zq::z2e(64);
        for a in [1u64, 3, 5, 0xDEAD_BEEF_1234_5677, u64::MAX] {
            assert!(r.is_unit(&a), "{a} should be a unit");
            let inv = r.inv(&a).unwrap();
            assert_eq!(r.mul(&a, &inv), 1, "a={a}");
        }
        for a in [0u64, 2, 4, 1 << 20] {
            assert!(!r.is_unit(&a));
            assert!(r.inv(&a).is_none());
        }
    }

    #[test]
    fn units_and_inverses_z3e() {
        let r = Zq::new(3, 4); // 81
        for a in 0..81u64 {
            if a % 3 != 0 {
                let inv = r.inv(&a).unwrap();
                assert_eq!(r.mul(&a, &inv), 1, "a={a}");
            } else {
                assert!(r.inv(&a).is_none());
            }
        }
    }

    #[test]
    fn field_case_e1() {
        // Z_p with e = 1 is GF(p); inverse = Fermat only, no Hensel steps.
        let r = Zq::new(7, 1);
        for a in 1..7u64 {
            assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), 1);
        }
    }

    #[test]
    fn exceptional_points_z2() {
        let r = Zq::z2e(64);
        let pts = r.exceptional_points(2).unwrap();
        assert_eq!(pts, vec![0, 1]);
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(3).is_err(), "Z_2^e has only 2");
    }

    #[test]
    fn exceptional_points_z7() {
        let r = Zq::new(7, 2);
        let pts = r.exceptional_points(7).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(8).is_err());
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let r = Zq::z2e(16);
        let a = 12345u64 & 0xFFFF;
        let mut acc = 1u64;
        for n in 0..20u32 {
            assert_eq!(r.pow_u128(&a, n as u128), acc);
            acc = r.mul(&acc, &a);
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let r = Zq::z2e(64);
        let mut buf = Vec::new();
        let vals = [0u64, 1, u64::MAX, 0x0123_4567_89AB_CDEF];
        for v in &vals {
            r.write_elem(v, &mut buf);
        }
        assert_eq!(buf.len(), vals.len() * r.elem_bytes());
        let mut pos = 0;
        for v in &vals {
            assert_eq!(r.read_elem(&buf, &mut pos), *v);
        }
    }

    #[test]
    fn bulk_slice_io_matches_per_element() {
        let r = Zq::z2e(64);
        let vals = [0u64, 1, u64::MAX, 0x0123_4567_89AB_CDEF, 42];
        let mut per_elem = Vec::new();
        for v in &vals {
            r.write_elem(v, &mut per_elem);
        }
        let mut bulk = vec![0xAAu8; 3]; // pre-existing bytes must be kept
        r.write_slice(&vals, &mut bulk);
        assert_eq!(&bulk[3..], per_elem.as_slice());
        let mut pos = 3;
        assert_eq!(r.read_slice(&bulk, &mut pos, vals.len()), vals);
        assert_eq!(pos, bulk.len());
        // zero-length slice is a no-op
        let mut pos = 0;
        assert_eq!(r.read_slice(&[], &mut pos, 0), Vec::<u64>::new());
        assert_eq!(pos, 0);
    }

    #[test]
    fn from_i64_signed() {
        let r = Zq::z2e(8);
        assert_eq!(r.from_i64(-1), 255);
        assert_eq!(r.from_i64(300), 44);
    }

    #[test]
    fn primality_helper() {
        assert!(is_small_prime(2));
        assert!(is_small_prime(3));
        assert!(is_small_prime(65537));
        assert!(!is_small_prime(1));
        assert!(!is_small_prime(91));
    }

    #[test]
    fn dot_and_sum() {
        let r = Zq::z2e(64);
        let xs = [1u64, 2, 3];
        let ys = [4u64, 5, 6];
        assert_eq!(r.dot(&xs, &ys), 32);
        assert_eq!(r.sum(&xs), 6);
    }
}
