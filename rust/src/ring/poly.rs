//! Dense univariate polynomials over any [`Ring`], little-endian coefficient
//! vectors. Supports the operations the coding layer and the fast
//! evaluation/interpolation algorithms need: add/sub/mul, division by monic
//! divisors, scaling, evaluation, derivative.
//!
//! Polynomials are plain `Vec<R::Elem>`; the ring context is passed to every
//! operation (same convention as the rest of the crate).

use super::traits::Ring;

/// Remove trailing zero coefficients (the zero polynomial is the empty vec).
pub fn trim<R: Ring>(ring: &R, mut a: Vec<R::Elem>) -> Vec<R::Elem> {
    while let Some(last) = a.last() {
        if ring.is_zero(last) {
            a.pop();
        } else {
            break;
        }
    }
    a
}

/// Degree; the zero polynomial has degree −1.
pub fn deg<R: Ring>(_ring: &R, a: &[R::Elem]) -> isize {
    a.len() as isize - 1
}

pub fn add<R: Ring>(ring: &R, a: &[R::Elem], b: &[R::Elem]) -> Vec<R::Elem> {
    let n = a.len().max(b.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = a.get(i).cloned().unwrap_or_else(|| ring.zero());
        let y = b.get(i).cloned().unwrap_or_else(|| ring.zero());
        out.push(ring.add(&x, &y));
    }
    trim(ring, out)
}

pub fn sub<R: Ring>(ring: &R, a: &[R::Elem], b: &[R::Elem]) -> Vec<R::Elem> {
    let n = a.len().max(b.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = a.get(i).cloned().unwrap_or_else(|| ring.zero());
        let y = b.get(i).cloned().unwrap_or_else(|| ring.zero());
        out.push(ring.sub(&x, &y));
    }
    trim(ring, out)
}

/// Schoolbook product. Quadratic, but polynomial degrees on the master are
/// bounded by the recovery threshold (≤ a few hundred); the subproduct-tree
/// algorithms in [`super::eval`] only multiply short polynomials.
pub fn mul<R: Ring>(ring: &R, a: &[R::Elem], b: &[R::Elem]) -> Vec<R::Elem> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let mut out = vec![ring.zero(); a.len() + b.len() - 1];
    for (i, ai) in a.iter().enumerate() {
        if ring.is_zero(ai) {
            continue;
        }
        for (j, bj) in b.iter().enumerate() {
            ring.mul_add_assign(&mut out[i + j], ai, bj);
        }
    }
    trim(ring, out)
}

/// Multiply by a scalar.
pub fn scale<R: Ring>(ring: &R, a: &[R::Elem], s: &R::Elem) -> Vec<R::Elem> {
    trim(ring, a.iter().map(|c| ring.mul(c, s)).collect())
}

/// `(quotient, remainder)` of `a / b` where the leading coefficient of `b`
/// must be a unit (always true for the monic subproducts we divide by).
pub fn divrem<R: Ring>(ring: &R, a: &[R::Elem], b: &[R::Elem]) -> (Vec<R::Elem>, Vec<R::Elem>) {
    let b = trim(ring, b.to_vec());
    assert!(!b.is_empty(), "division by the zero polynomial");
    let lead_inv = ring
        .inv(b.last().unwrap())
        .expect("divisor leading coefficient must be a unit");
    let db = b.len() - 1;
    let mut r = trim(ring, a.to_vec());
    if db == 0 {
        // dividing by a unit constant
        let q: Vec<R::Elem> = r.iter().map(|c| ring.mul(c, &lead_inv)).collect();
        return (trim(ring, q), vec![]);
    }
    if r.len() <= db {
        return (vec![], r);
    }
    let mut q = vec![ring.zero(); r.len() - db];
    while r.len() > db {
        let k = r.len() - 1 - db;
        let c = ring.mul(r.last().unwrap(), &lead_inv);
        q[k] = c.clone();
        for (i, bi) in b.iter().enumerate().take(db) {
            let t = ring.mul(&c, bi);
            r[k + i] = ring.sub(&r[k + i], &t);
        }
        // The top coefficient is eliminated exactly.
        r.pop();
        r = trim(ring, r);
    }
    (trim(ring, q), trim(ring, r))
}

/// Horner evaluation.
pub fn eval<R: Ring>(ring: &R, a: &[R::Elem], x: &R::Elem) -> R::Elem {
    let mut acc = ring.zero();
    for c in a.iter().rev() {
        acc = ring.mul(&acc, x);
        ring.add_assign(&mut acc, c);
    }
    acc
}

/// Formal derivative.
pub fn derivative<R: Ring>(ring: &R, a: &[R::Elem]) -> Vec<R::Elem> {
    if a.len() <= 1 {
        return vec![];
    }
    let mut out = Vec::with_capacity(a.len() - 1);
    for (i, c) in a.iter().enumerate().skip(1) {
        // multiply by the integer i (as a ring element: i · 1)
        let mut k = ring.zero();
        let one = ring.one();
        // binary expansion of i for O(log i) additions
        let mut bit = 1usize;
        let mut pow2 = one.clone();
        while bit <= i {
            if i & bit != 0 {
                ring.add_assign(&mut k, &pow2);
            }
            bit <<= 1;
            pow2 = ring.add(&pow2, &pow2);
        }
        out.push(ring.mul(c, &k));
    }
    trim(ring, out)
}

/// `Π (x − p_i)` — the monic polynomial with the given roots.
pub fn from_roots<R: Ring>(ring: &R, pts: &[R::Elem]) -> Vec<R::Elem> {
    let mut acc = vec![ring.one()];
    for p in pts {
        acc = mul(ring, &acc, &[ring.neg(p), ring.one()]);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::zq::Zq;
    use crate::ring::extension::Extension;
    use crate::util::rng::Rng64;

    fn rand_poly(ring: &Zq, deg: usize, rng: &mut Rng64) -> Vec<u64> {
        trim(ring, (0..=deg).map(|_| ring.random(rng)).collect())
    }

    #[test]
    fn mul_matches_naive_identity() {
        let r = Zq::z2e(64);
        // (x+1)(x-1) = x^2 - 1
        let a = vec![1u64, 1];
        let b = vec![r.neg(&1), 1];
        assert_eq!(mul(&r, &a, &b), vec![r.neg(&1), 0, 1]);
    }

    #[test]
    fn divrem_reconstructs() {
        let r = Zq::z2e(64);
        let mut rng = Rng64::seeded(31);
        for _ in 0..30 {
            let a = rand_poly(&r, 12, &mut rng);
            // monic divisor
            let mut b = rand_poly(&r, 5, &mut rng);
            b.resize(6, 0);
            b[5] = 1;
            let (q, rem) = divrem(&r, &a, &b);
            let recon = add(&r, &mul(&r, &q, &b), &rem);
            assert_eq!(trim(&r, recon), trim(&r, a.clone()));
            assert!(deg(&r, &rem) < deg(&r, &b));
        }
    }

    #[test]
    fn divrem_by_unit_leading_nonmonic() {
        let r = Zq::z2e(64);
        let a = vec![5u64, 7, 9, 11];
        let b = vec![2u64, 3]; // leading 3 is a unit mod 2^64
        let (q, rem) = divrem(&r, &a, &b);
        let recon = add(&r, &mul(&r, &q, &b), &rem);
        assert_eq!(recon, a);
    }

    #[test]
    fn eval_horner() {
        let r = Zq::z2e(64);
        // f(x) = 3 + 2x + x^2 at x=5 → 3 + 10 + 25 = 38
        assert_eq!(eval(&r, &[3, 2, 1], &5), 38);
        assert_eq!(eval(&r, &[], &5), 0);
    }

    #[test]
    fn from_roots_vanishes() {
        let r = Zq::z2e(64);
        let pts = vec![0u64, 1, 7, 13];
        let m = from_roots(&r, &pts);
        assert_eq!(m.len(), 5);
        for p in &pts {
            assert_eq!(eval(&r, &m, p), 0);
        }
        assert_eq!(*m.last().unwrap(), 1, "monic");
    }

    #[test]
    fn derivative_power_rule() {
        let r = Zq::z2e(64);
        // d/dx (x^3 + 4x + 9) = 3x^2 + 4
        assert_eq!(derivative(&r, &[9, 4, 0, 1]), vec![4, 0, 3]);
    }

    #[test]
    fn works_over_extension() {
        let ext = Extension::new(Zq::z2e(32), 3);
        let mut rng = Rng64::seeded(32);
        let a: Vec<_> = (0..5).map(|_| ext.random(&mut rng)).collect();
        let b: Vec<_> = (0..3).map(|_| ext.random(&mut rng)).collect();
        let ab = mul(&ext, &a, &b);
        // eval(ab, x) == eval(a,x)*eval(b,x)
        let x = ext.random(&mut rng);
        assert_eq!(
            eval(&ext, &ab, &x),
            ext.mul(&eval(&ext, &a, &x), &eval(&ext, &b, &x))
        );
    }
}
