//! `GaloisRing` — `GR(p^e, d) = Z_{p^e}[x]/(f(x))` with `f` monic of degree
//! `d` and `f̄ = f mod p` irreducible over `GF(p)` (Section II-B).
//!
//! Elements are little-endian coefficient vectors of length `d` over
//! [`Zq`]. Multiplication is schoolbook + reduction by the monic modulus.
//! Inversion comes from the generic residue-Fermat + Newton–Hensel routine in
//! the [`Ring`] trait.

use super::gfp::{Gfq, GfqElem};
use super::irreducible::find_irreducible;
use super::traits::Ring;
use super::zq::Zq;
use crate::util::rng::Rng64;

/// A ring that can serve as the base of a tower [`super::extension::Extension`]:
/// it exposes its residue field as a concrete [`Gfq`] and can lift residue
/// elements back into itself (digit lift).
pub trait ExtensibleRing: Ring {
    /// The residue field `GF(p^D)` with its canonical modulus.
    fn residue_field(&self) -> Gfq;
    /// Digit lift of a residue element (coefficients in `{0..p−1}` reused
    /// verbatim as ring coefficients).
    fn lift_residue(&self, r: &GfqElem) -> Self::Elem;
}

impl ExtensibleRing for Zq {
    fn residue_field(&self) -> Gfq {
        Gfq::new(self.p(), vec![0, 1]) // GF(p) presented as GF(p)[x]/(x)
    }
    fn lift_residue(&self, r: &GfqElem) -> u64 {
        debug_assert_eq!(r.len(), 1);
        r[0]
    }
}

/// The Galois ring `GR(p^e, d)`.
#[derive(Clone, Debug, PartialEq)]
pub struct GaloisRing {
    zq: Zq,
    d: usize,
    /// Monic modulus, length `d+1`, coefficients in `Z_{p^e}` (actually in
    /// `{0..p−1}` — direct lift of the irreducible residue polynomial).
    modulus: Vec<u64>,
}

/// Element of `GR(p^e, d)`: little-endian coefficients, length = `d`.
pub type GrElem = Vec<u64>;

impl GaloisRing {
    /// Construct `GR(p^e, d)` with the lexicographically-first irreducible
    /// modulus (deterministic across runs).
    pub fn new(p: u64, e: u32, d: usize) -> GaloisRing {
        assert!(d >= 1);
        let zq = Zq::new(p, e);
        let gfp = Gfq::new(p, vec![0, 1]);
        let hbar = find_irreducible(&gfp, d);
        let modulus: Vec<u64> = hbar.iter().map(|c| c[0]).collect();
        GaloisRing { zq, d, modulus }
    }

    /// Construct with an explicit monic modulus (must be irreducible mod p —
    /// verified).
    pub fn with_modulus(p: u64, e: u32, modulus: Vec<u64>) -> anyhow::Result<GaloisRing> {
        let zq = Zq::new(p, e);
        let d = modulus.len() - 1;
        anyhow::ensure!(d >= 1, "modulus must have degree >= 1");
        anyhow::ensure!(zq.reduce(modulus[d]) == 1, "modulus must be monic");
        let gfp = Gfq::new(p, vec![0, 1]);
        let hbar: Vec<GfqElem> = modulus.iter().map(|&c| vec![c % p]).collect();
        anyhow::ensure!(
            super::irreducible::is_irreducible(&gfp, &hbar),
            "modulus is not irreducible mod p"
        );
        Ok(GaloisRing { zq, d, modulus })
    }

    /// The coefficient ring `Z_{p^e}`.
    pub fn coeff_ring(&self) -> &Zq {
        &self.zq
    }

    /// The defining modulus (monic, length d+1).
    pub fn modulus(&self) -> &[u64] {
        &self.modulus
    }

    /// Embed a scalar `c ∈ Z_{p^e}` as the constant element.
    pub fn from_scalar(&self, c: u64) -> GrElem {
        let mut v = vec![0u64; self.d];
        v[0] = self.zq.reduce(c);
        v
    }

    /// Element from coefficient slice (reduced; padded/truncated to d).
    pub fn from_coeffs(&self, coeffs: &[u64]) -> GrElem {
        let mut v = vec![0u64; self.d];
        for (i, &c) in coeffs.iter().enumerate().take(self.d) {
            v[i] = self.zq.reduce(c);
        }
        v
    }

    /// Reduce a raw product (length ≤ 2d−1) by the monic modulus, in place,
    /// returning the low `d` coefficients.
    fn reduce_poly(&self, mut prod: Vec<u64>) -> GrElem {
        let d = self.d;
        for k in (d..prod.len()).rev() {
            let c = prod[k];
            if c == 0 {
                continue;
            }
            prod[k] = 0;
            // x^k ≡ −Σ_{i<d} f_i x^{k−d+i}  (f monic)
            for i in 0..d {
                if self.modulus[i] != 0 {
                    let delta = self.zq.mul(&c, &self.modulus[i]);
                    prod[k - d + i] = self.zq.sub(&prod[k - d + i], &delta);
                }
            }
        }
        prod.truncate(d);
        prod
    }

    /// The Teichmüller lift of a residue-field element `r`: the unique
    /// element `ζ` with `ζ^(p^d) = ζ` reducing to `r` mod p. Computed as
    /// `lift(r)^(p^d)` iterated `e−1` times. (Used in tests; the exceptional
    /// sets used by the codes are plain digit lifts, which are cheaper.)
    pub fn teichmuller(&self, r: &GfqElem) -> GrElem {
        let mut t = self.lift_residue(r);
        let pd = (self.p() as u128).pow(self.d as u32);
        for _ in 0..self.e().saturating_sub(1) {
            t = self.pow_u128(&t, pd);
        }
        t
    }
}

impl Ring for GaloisRing {
    type Elem = GrElem;

    #[inline]
    fn p(&self) -> u64 {
        self.zq.p()
    }
    #[inline]
    fn e(&self) -> u32 {
        self.zq.e()
    }
    #[inline]
    fn degree(&self) -> usize {
        self.d
    }

    fn zero(&self) -> GrElem {
        vec![0; self.d]
    }

    fn one(&self) -> GrElem {
        self.from_scalar(1)
    }

    fn add(&self, a: &GrElem, b: &GrElem) -> GrElem {
        a.iter().zip(b).map(|(x, y)| self.zq.add(x, y)).collect()
    }

    fn sub(&self, a: &GrElem, b: &GrElem) -> GrElem {
        a.iter().zip(b).map(|(x, y)| self.zq.sub(x, y)).collect()
    }

    fn neg(&self, a: &GrElem) -> GrElem {
        a.iter().map(|x| self.zq.neg(x)).collect()
    }

    fn mul(&self, a: &GrElem, b: &GrElem) -> GrElem {
        let d = self.d;
        if d == 1 {
            return vec![self.zq.mul(&a[0], &b[0])];
        }
        let mut prod = vec![0u64; 2 * d - 1];
        for (i, ai) in a.iter().enumerate() {
            if *ai == 0 {
                continue;
            }
            for (j, bj) in b.iter().enumerate() {
                self.zq.mul_add_assign(&mut prod[i + j], ai, bj);
            }
        }
        self.reduce_poly(prod)
    }

    fn add_assign(&self, a: &mut GrElem, b: &GrElem) {
        for (x, y) in a.iter_mut().zip(b) {
            self.zq.add_assign(x, y);
        }
    }

    fn is_zero(&self, a: &GrElem) -> bool {
        a.iter().all(|&c| c == 0)
    }

    fn is_unit(&self, a: &GrElem) -> bool {
        // unit ⟺ a ≢ 0 (mod p) ⟺ some coefficient not divisible by p
        a.iter().any(|&c| c % self.p() != 0)
    }

    fn exceptional_points(&self, n: usize) -> anyhow::Result<Vec<GrElem>> {
        let pd = self.residue_size();
        anyhow::ensure!(
            (n as u128) <= pd,
            "{} has only {} exceptional points, {} requested",
            self.name(),
            pd,
            n
        );
        let rf = self.residue_field();
        Ok((0..n as u128)
            .map(|i| self.lift_residue(&rf.element_from_index(i)))
            .collect())
    }

    fn elem_bytes(&self) -> usize {
        8 * self.d
    }

    fn write_elem(&self, a: &GrElem, out: &mut Vec<u8>) {
        for c in a {
            out.extend_from_slice(&c.to_le_bytes());
        }
    }

    fn read_elem(&self, buf: &[u8], pos: &mut usize) -> GrElem {
        let mut v = Vec::with_capacity(self.d);
        for _ in 0..self.d {
            let mut b = [0u8; 8];
            b.copy_from_slice(&buf[*pos..*pos + 8]);
            *pos += 8;
            v.push(u64::from_le_bytes(b));
        }
        v
    }

    fn random(&self, rng: &mut Rng64) -> GrElem {
        (0..self.d).map(|_| self.zq.random(rng)).collect()
    }

    fn name(&self) -> String {
        format!("GR({}^{}, {})", self.p(), self.e(), self.d)
    }
}

impl ExtensibleRing for GaloisRing {
    fn residue_field(&self) -> Gfq {
        let p = self.p();
        let hbar: Vec<GfqElem> = self.modulus.iter().map(|&c| vec![c % p]).collect();
        // Gfq wants plain u64 coefficients for its modulus over GF(p):
        let modulus: Vec<u64> = hbar.iter().map(|c| c[0]).collect();
        Gfq::new(p, modulus)
    }
    fn lift_residue(&self, r: &GfqElem) -> GrElem {
        debug_assert_eq!(r.len(), self.d);
        r.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::traits::is_exceptional_sequence;

    fn gr_2e64_3() -> GaloisRing {
        GaloisRing::new(2, 64, 3)
    }

    #[test]
    fn construct_standard_rings() {
        // The paper's experimental rings.
        for d in [1usize, 3, 4, 5] {
            let r = GaloisRing::new(2, 64, d);
            assert_eq!(r.degree(), d);
            assert_eq!(r.residue_size(), 1u128 << d);
        }
        let r = GaloisRing::new(3, 2, 2);
        assert_eq!(r.residue_size(), 9);
    }

    #[test]
    fn ring_axioms_smoke() {
        let r = gr_2e64_3();
        let mut rng = Rng64::seeded(11);
        for _ in 0..50 {
            let a = r.random(&mut rng);
            let b = r.random(&mut rng);
            let c = r.random(&mut rng);
            // commutativity, associativity, distributivity
            assert_eq!(r.add(&a, &b), r.add(&b, &a));
            assert_eq!(r.mul(&a, &b), r.mul(&b, &a));
            assert_eq!(r.mul(&r.mul(&a, &b), &c), r.mul(&a, &r.mul(&b, &c)));
            assert_eq!(
                r.mul(&a, &r.add(&b, &c)),
                r.add(&r.mul(&a, &b), &r.mul(&a, &c))
            );
            // identities
            assert_eq!(r.add(&a, &r.zero()), a);
            assert_eq!(r.mul(&a, &r.one()), a);
            assert_eq!(r.add(&a, &r.neg(&a)), r.zero());
        }
    }

    #[test]
    fn inverses() {
        let r = gr_2e64_3();
        let mut rng = Rng64::seeded(12);
        let mut tested = 0;
        while tested < 25 {
            let a = r.random(&mut rng);
            if !r.is_unit(&a) {
                assert!(r.inv(&a).is_none());
                continue;
            }
            let inv = r.inv(&a).unwrap();
            assert_eq!(r.mul(&a, &inv), r.one());
            tested += 1;
        }
    }

    #[test]
    fn inverses_odd_characteristic() {
        let r = GaloisRing::new(3, 4, 2); // GR(81, 2)
        let mut rng = Rng64::seeded(13);
        for _ in 0..25 {
            let a = r.random(&mut rng);
            if r.is_unit(&a) {
                assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), r.one());
            }
        }
    }

    #[test]
    fn galois_field_case() {
        // GR(p, d) = GF(p^d): every nonzero element is a unit.
        let r = GaloisRing::new(2, 1, 4);
        let mut rng = Rng64::seeded(14);
        for _ in 0..30 {
            let a = r.random(&mut rng);
            if !r.is_zero(&a) {
                assert!(r.is_unit(&a));
                assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), r.one());
            }
        }
    }

    #[test]
    fn exceptional_set() {
        let r = gr_2e64_3();
        let pts = r.exceptional_points(8).unwrap(); // 2^3 = 8 available
        assert_eq!(pts.len(), 8);
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(9).is_err());
    }

    #[test]
    fn exceptional_set_gr_2e64_4() {
        let r = GaloisRing::new(2, 64, 4);
        let pts = r.exceptional_points(16).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
    }

    #[test]
    fn teichmuller_fixed_point() {
        let r = gr_2e64_3();
        let rf = r.residue_field();
        for i in 1..8u128 {
            let z = r.teichmuller(&rf.element_from_index(i));
            let pd = 8u128;
            assert_eq!(r.pow_u128(&z, pd), z, "ζ^(p^d) = ζ");
            assert!(r.is_unit(&z));
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let r = gr_2e64_3();
        let mut rng = Rng64::seeded(15);
        let a = r.random(&mut rng);
        let mut buf = Vec::new();
        r.write_elem(&a, &mut buf);
        assert_eq!(buf.len(), r.elem_bytes());
        let mut pos = 0;
        assert_eq!(r.read_elem(&buf, &mut pos), a);
    }

    #[test]
    fn scalar_embedding_homomorphic() {
        let r = gr_2e64_3();
        let zq = r.coeff_ring().clone();
        let a = 0xABCDu64;
        let b = 0x1234_5678u64;
        assert_eq!(
            r.mul(&r.from_scalar(a), &r.from_scalar(b)),
            r.from_scalar(zq.mul(&a, &b))
        );
        assert_eq!(
            r.add(&r.from_scalar(a), &r.from_scalar(b)),
            r.from_scalar(zq.add(&a, &b))
        );
    }

    #[test]
    fn with_modulus_validates() {
        // x^2 + 1 is reducible mod 2 — must be rejected.
        assert!(GaloisRing::with_modulus(2, 64, vec![1, 0, 1]).is_err());
        // x^2 + x + 1 is fine.
        assert!(GaloisRing::with_modulus(2, 64, vec![1, 1, 1]).is_ok());
    }
}
