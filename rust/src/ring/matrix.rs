//! Dense row-major matrices over any [`Ring`] — the element-generic AoS
//! representation.
//!
//! The element type is generic (`Matrix<E>`); ring context is passed to each
//! operation, matching the rest of the crate. `Matrix` is the *user-facing*
//! input/output type and the container for scalar-sized internal systems
//! (e.g. the CSA decoder's Cauchy–Vandermonde inverse). The worker-node hot
//! path and everything on the encode → wire → worker → decode path instead
//! use the flat plane-major [`crate::ring::plane::PlaneMatrix`], which
//! stores an extension-ring matrix as `m` contiguous base-ring coefficient
//! planes (no per-element heap allocation); convert between the two with
//! [`crate::ring::plane::PlaneMatrix::from_aos`] /
//! [`crate::ring::plane::PlaneMatrix::to_aos`].

use super::traits::Ring;
use crate::util::rng::Rng64;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<E> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<E>,
}

impl<E: Clone> Matrix<E> {
    pub fn from_vec(rows: usize, cols: usize, data: Vec<E>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> E) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &E {
        &self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: E) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[E] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of the `h × w` block with top-left corner `(i0, j0)`.
    pub fn block(&self, i0: usize, j0: usize, h: usize, w: usize) -> Matrix<E> {
        assert!(i0 + h <= self.rows && j0 + w <= self.cols);
        let mut data = Vec::with_capacity(h * w);
        for i in 0..h {
            data.extend_from_slice(
                &self.data[(i0 + i) * self.cols + j0..(i0 + i) * self.cols + j0 + w],
            );
        }
        Matrix { rows: h, cols: w, data }
    }

    /// Partition into a `gr × gc` grid of equal blocks (dims must divide).
    /// Returned row-major: `out[a*gc + b]` is block (a, b).
    pub fn partition_grid(&self, gr: usize, gc: usize) -> Vec<Matrix<E>> {
        assert!(self.rows % gr == 0, "rows {} not divisible by {}", self.rows, gr);
        assert!(self.cols % gc == 0, "cols {} not divisible by {}", self.cols, gc);
        let bh = self.rows / gr;
        let bw = self.cols / gc;
        let mut out = Vec::with_capacity(gr * gc);
        for a in 0..gr {
            for b in 0..gc {
                out.push(self.block(a * bh, b * bw, bh, bw));
            }
        }
        out
    }

    /// Inverse of [`Matrix::partition_grid`].
    pub fn stitch_grid(blocks: &[Matrix<E>], gr: usize, gc: usize) -> Matrix<E> {
        assert_eq!(blocks.len(), gr * gc);
        let bh = blocks[0].rows;
        let bw = blocks[0].cols;
        let mut out: Vec<E> = Vec::with_capacity(gr * gc * bh * bw);
        for a in 0..gr {
            for i in 0..bh {
                for b in 0..gc {
                    let blk = &blocks[a * gc + b];
                    assert_eq!(blk.rows, bh);
                    assert_eq!(blk.cols, bw);
                    out.extend_from_slice(blk.row(i));
                }
            }
        }
        Matrix { rows: gr * bh, cols: gc * bw, data: out }
    }

    pub fn transpose(&self) -> Matrix<E> {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.at(j, i).clone())
    }

    /// Elementwise map into a (possibly different) element type.
    pub fn map<F, T: Clone>(&self, f: F) -> Matrix<T>
    where
        F: Fn(&E) -> T,
    {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(f).collect(),
        }
    }
}

impl<E: Clone + PartialEq> Matrix<E> {
    /// All-zero matrix.
    pub fn zeros<R: Ring<Elem = E>>(ring: &R, rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![ring.zero(); rows * cols] }
    }

    /// Identity.
    pub fn identity<R: Ring<Elem = E>>(ring: &R, n: usize) -> Self {
        let mut m = Self::zeros(ring, n, n);
        for i in 0..n {
            m.set(i, i, ring.one());
        }
        m
    }

    /// Uniformly random matrix.
    pub fn random<R: Ring<Elem = E>>(ring: &R, rows: usize, cols: usize, rng: &mut Rng64) -> Self {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| ring.random(rng)).collect(),
        }
    }

    pub fn is_zero<R: Ring<Elem = E>>(&self, ring: &R) -> bool {
        self.data.iter().all(|x| ring.is_zero(x))
    }

    pub fn add<R: Ring<Elem = E>>(ring: &R, a: &Self, b: &Self) -> Self {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        Matrix {
            rows: a.rows,
            cols: a.cols,
            data: a.data.iter().zip(&b.data).map(|(x, y)| ring.add(x, y)).collect(),
        }
    }

    pub fn sub<R: Ring<Elem = E>>(ring: &R, a: &Self, b: &Self) -> Self {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        Matrix {
            rows: a.rows,
            cols: a.cols,
            data: a.data.iter().zip(&b.data).map(|(x, y)| ring.sub(x, y)).collect(),
        }
    }

    /// `self += other`.
    pub fn add_assign<R: Ring<Elem = E>>(&mut self, ring: &R, other: &Self) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            ring.add_assign(x, y);
        }
    }

    /// `self = self · s` (scalar). Delegates to the ring's
    /// [`Ring::slice_scale_assign`] hook (SIMD-dispatched for `Zq`).
    pub fn scale_assign<R: Ring<Elem = E>>(&mut self, ring: &R, s: &E) {
        ring.slice_scale_assign(&mut self.data, s);
    }

    /// `self += s · other` — the decode/Horner workhorse. Delegates to the
    /// ring's [`Ring::mat_axpy`] hook (plane-decomposed for extensions).
    pub fn axpy<R: Ring<Elem = E>>(&mut self, ring: &R, s: &E, other: &Self) {
        ring.mat_axpy(self, s, other);
    }

    /// Matrix product. Delegates to the ring's [`Ring::mat_mul`] hook: the
    /// generic ikj loop for scalar rings, the plane-decomposed kernel for
    /// tower extensions (§Perf).
    pub fn matmul<R: Ring<Elem = E>>(ring: &R, a: &Self, b: &Self) -> Self {
        ring.mat_mul(a, b)
    }

    /// Inverse of a square matrix over the ring, by Gauss–Jordan with
    /// *unit-pivot* search: over a local ring (every Galois ring is one) a
    /// matrix is invertible iff its determinant is a unit, in which case at
    /// every elimination step some candidate pivot is a unit (the reduction
    /// mod p is an invertible matrix over the residue field). Returns `None`
    /// if no unit pivot exists at some step (singular matrix).
    ///
    /// Used by the CSA decoder to invert Cauchy–Vandermonde systems.
    pub fn invert<R: Ring<Elem = E>>(&self, ring: &R) -> Option<Matrix<E>> {
        assert_eq!(self.rows, self.cols, "inverse needs a square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Self::identity(ring, n);
        for col in 0..n {
            // find a unit pivot at or below the diagonal
            let pivot_row = (col..n).find(|&r| ring.is_unit(a.at(r, col)))?;
            if pivot_row != col {
                for j in 0..n {
                    a.data.swap(pivot_row * n + j, col * n + j);
                    inv.data.swap(pivot_row * n + j, col * n + j);
                }
            }
            let pinv = ring.inv(a.at(col, col)).expect("unit pivot");
            for j in 0..n {
                let v = ring.mul(a.at(col, j), &pinv);
                a.set(col, j, v);
                let v = ring.mul(inv.at(col, j), &pinv);
                inv.set(col, j, v);
            }
            for r in 0..n {
                if r == col || ring.is_zero(a.at(r, col)) {
                    continue;
                }
                let factor = a.at(r, col).clone();
                for j in 0..n {
                    let t = ring.mul(&factor, a.at(col, j));
                    a.set(r, j, ring.sub(a.at(r, j), &t));
                    let t = ring.mul(&factor, inv.at(col, j));
                    inv.set(r, j, ring.sub(inv.at(r, j), &t));
                }
            }
        }
        Some(inv)
    }

    /// Serialized byte size under `ring`'s canonical encoding.
    pub fn byte_len<R: Ring<Elem = E>>(&self, ring: &R) -> usize {
        8 + 8 + self.data.len() * ring.elem_bytes()
    }

    /// Serialize: `rows (u64 LE) | cols (u64 LE) | elements`. Elements move
    /// through [`Ring::write_slice`] — a single block copy for `Zq`.
    pub fn to_bytes<R: Ring<Elem = E>>(&self, ring: &R) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len(ring));
        self.write_bytes_into(ring, &mut out);
        out
    }

    /// Append the serialized form to a borrowed buffer (the pool-leased
    /// zero-copy path — see [`crate::util::bytepool`]).
    pub fn write_bytes_into<R: Ring<Elem = E>>(&self, ring: &R, out: &mut Vec<u8>) {
        out.reserve(self.byte_len(ring));
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        ring.write_slice(&self.data, out);
    }

    /// Deserialize, validating every length before any allocation or read:
    /// truncated or oversized payloads yield an `Err`, never a panic.
    pub fn from_bytes<R: Ring<Elem = E>>(ring: &R, buf: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(buf.len() >= 16, "matrix header truncated: {} of 16 bytes", buf.len());
        let mut pos = 0;
        let mut b8 = [0u8; 8];
        b8.copy_from_slice(&buf[0..8]);
        let rows = u64::from_le_bytes(b8) as usize;
        b8.copy_from_slice(&buf[8..16]);
        let cols = u64::from_le_bytes(b8) as usize;
        pos += 16;
        let count = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("matrix shape {rows}x{cols} overflows"))?;
        let need = count
            .checked_mul(ring.elem_bytes())
            .ok_or_else(|| anyhow::anyhow!("matrix payload size overflows"))?;
        anyhow::ensure!(
            buf.len() - pos == need,
            "matrix payload is {} bytes, expected {need} for {rows}x{cols}",
            buf.len() - pos
        );
        // Length validated above; the bulk read (one block copy for `Zq`)
        // cannot run past the buffer.
        let data: Vec<E> = ring.read_slice(buf, &mut pos, count);
        Ok(Matrix { rows, cols, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;

    fn ring() -> Zq {
        Zq::z2e(64)
    }

    #[test]
    fn matmul_small_known() {
        let r = ring();
        let a = Matrix::from_vec(2, 2, vec![1u64, 2, 3, 4]);
        let b = Matrix::from_vec(2, 2, vec![5u64, 6, 7, 8]);
        let c = Matrix::matmul(&r, &a, &b);
        assert_eq!(c.data, vec![19, 22, 43, 50]);
    }

    #[test]
    fn matmul_identity() {
        let r = ring();
        let mut rng = Rng64::seeded(51);
        let a = Matrix::random(&r, 7, 7, &mut rng);
        let id = Matrix::identity(&r, 7);
        assert_eq!(Matrix::matmul(&r, &a, &id), a);
        assert_eq!(Matrix::matmul(&r, &id, &a), a);
    }

    #[test]
    fn matmul_associative_rect() {
        let r = ring();
        let mut rng = Rng64::seeded(52);
        let a = Matrix::random(&r, 4, 6, &mut rng);
        let b = Matrix::random(&r, 6, 3, &mut rng);
        let c = Matrix::random(&r, 3, 5, &mut rng);
        let left = Matrix::matmul(&r, &Matrix::matmul(&r, &a, &b), &c);
        let right = Matrix::matmul(&r, &a, &Matrix::matmul(&r, &b, &c));
        assert_eq!(left, right);
    }

    #[test]
    fn matmul_wraps_mod_2e64() {
        let r = ring();
        let a = Matrix::from_vec(1, 1, vec![u64::MAX]);
        let b = Matrix::from_vec(1, 1, vec![2u64]);
        assert_eq!(Matrix::matmul(&r, &a, &b).data, vec![u64::MAX - 1]);
    }

    #[test]
    fn partition_and_stitch_roundtrip() {
        let r = ring();
        let mut rng = Rng64::seeded(53);
        let a = Matrix::random(&r, 6, 8, &mut rng);
        for (gr, gc) in [(1, 1), (2, 2), (3, 4), (6, 8), (2, 4)] {
            let blocks = a.partition_grid(gr, gc);
            assert_eq!(blocks.len(), gr * gc);
            let b = Matrix::stitch_grid(&blocks, gr, gc);
            assert_eq!(a, b, "grid {gr}x{gc}");
        }
    }

    #[test]
    fn block_matmul_equals_full() {
        // (u,w) × (w,v) block-partition multiply must equal the flat product.
        let r = ring();
        let mut rng = Rng64::seeded(54);
        let a = Matrix::random(&r, 6, 4, &mut rng);
        let b = Matrix::random(&r, 4, 6, &mut rng);
        let (u, w, v) = (3, 2, 2);
        let ab = a.partition_grid(u, w);
        let bb = b.partition_grid(w, v);
        let mut cb = Vec::new();
        for i in 0..u {
            for l in 0..v {
                let mut acc = Matrix::zeros(&r, a.rows / u, b.cols / v);
                for k in 0..w {
                    let prod = Matrix::matmul(&r, &ab[i * w + k], &bb[k * v + l]);
                    acc.add_assign(&r, &prod);
                }
                cb.push(acc);
            }
        }
        let c = Matrix::stitch_grid(&cb, u, v);
        assert_eq!(c, Matrix::matmul(&r, &a, &b));
    }

    #[test]
    fn axpy_and_scale() {
        let r = ring();
        let mut rng = Rng64::seeded(55);
        let a = Matrix::random(&r, 3, 3, &mut rng);
        let b = Matrix::random(&r, 3, 3, &mut rng);
        let s = 7u64;
        let mut c = a.clone();
        c.axpy(&r, &s, &b);
        let expected = Matrix::add(&r, &a, &{
            let mut t = b.clone();
            t.scale_assign(&r, &s);
            t
        });
        assert_eq!(c, expected);
    }

    #[test]
    fn transpose_involution() {
        let r = ring();
        let mut rng = Rng64::seeded(56);
        let a = Matrix::random(&r, 3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn serialization_roundtrip_zq() {
        let r = ring();
        let mut rng = Rng64::seeded(57);
        let a = Matrix::random(&r, 4, 5, &mut rng);
        let bytes = a.to_bytes(&r);
        assert_eq!(bytes.len(), a.byte_len(&r));
        assert_eq!(Matrix::from_bytes(&r, &bytes).unwrap(), a);
        // truncated / oversized payloads are rejected, not panicked on
        assert!(Matrix::<u64>::from_bytes(&r, &bytes[..bytes.len() - 1]).is_err());
        assert!(Matrix::<u64>::from_bytes(&r, &bytes[..4]).is_err());
        let mut big = bytes.clone();
        big.push(0);
        assert!(Matrix::<u64>::from_bytes(&r, &big).is_err());
    }

    #[test]
    fn serialization_roundtrip_extension() {
        let ext = Extension::new(Zq::z2e(64), 3);
        let mut rng = Rng64::seeded(58);
        let a = Matrix::random(&ext, 3, 2, &mut rng);
        let bytes = a.to_bytes(&ext);
        assert_eq!(bytes.len(), 16 + 6 * 24);
        assert_eq!(Matrix::from_bytes(&ext, &bytes).unwrap(), a);
    }

    #[test]
    fn matmul_over_extension_matches_scalar_blocks() {
        // multiply constant-embedded matrices in the tower, compare with Zq
        let zq = Zq::z2e(64);
        let ext = Extension::new(zq.clone(), 3);
        let mut rng = Rng64::seeded(59);
        let a = Matrix::random(&zq, 3, 3, &mut rng);
        let b = Matrix::random(&zq, 3, 3, &mut rng);
        let ae = a.map(|x| ext.from_base(x));
        let be = b.map(|x| ext.from_base(x));
        let ce = Matrix::matmul(&ext, &ae, &be);
        let c = Matrix::matmul(&zq, &a, &b);
        assert_eq!(ce, c.map(|x| ext.from_base(x)));
    }
}
