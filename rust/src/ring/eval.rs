//! Multipoint evaluation and interpolation over exceptional sets
//! (Lemma II.1, [14, Cor. 10.8 & 10.12]).
//!
//! Two implementations of each, cross-validated in tests and benchmarked in
//! `rust/benches/eval_crossover.rs`:
//!
//! * **naive** — Horner per point / Lagrange basis accumulation, `O(n·deg)` /
//!   `O(n²)`. Unbeatable for the small `N ≤ 64` of the paper's experiments.
//! * **fast** — subproduct-tree remainder evaluation and tree-combined
//!   interpolation, `O(n log² n)` ring operations; the asymptotics the paper's
//!   complexity rows assume.
//!
//! Interpolation requires the points to form an *exceptional sequence*
//! (pairwise differences invertible) — exactly what
//! [`crate::ring::traits::Ring::exceptional_points`] provides; `M'(x_i)` is
//! then a unit and the Lagrange denominators invert.

use super::poly;
use super::traits::Ring;

/// Evaluate `f` at every point by Horner. `O(pts.len() · deg f)`.
pub fn eval_many_naive<R: Ring>(ring: &R, f: &[R::Elem], pts: &[R::Elem]) -> Vec<R::Elem> {
    pts.iter().map(|x| poly::eval(ring, f, x)).collect()
}

/// Subproduct tree over a point set: `tree[0]` are the leaves `(x − x_i)`,
/// each higher level the product of adjacent pairs; the last level has a
/// single polynomial `M(x) = Π (x − x_i)`.
pub struct SubproductTree<R: Ring> {
    /// `levels[l][k]`: product of leaves `k·2^l .. min((k+1)·2^l, n)`.
    pub levels: Vec<Vec<Vec<R::Elem>>>,
    pub n: usize,
}

impl<R: Ring> SubproductTree<R> {
    pub fn build(ring: &R, pts: &[R::Elem]) -> Self {
        let n = pts.len();
        assert!(n > 0);
        let mut levels: Vec<Vec<Vec<R::Elem>>> = Vec::new();
        let leaves: Vec<Vec<R::Elem>> = pts
            .iter()
            .map(|p| vec![ring.neg(p), ring.one()])
            .collect();
        levels.push(leaves);
        while levels.last().unwrap().len() > 1 {
            let prev = levels.last().unwrap();
            let mut next = Vec::with_capacity(prev.len().div_ceil(2));
            let mut i = 0;
            while i < prev.len() {
                if i + 1 < prev.len() {
                    next.push(poly::mul(ring, &prev[i], &prev[i + 1]));
                } else {
                    next.push(prev[i].clone());
                }
                i += 2;
            }
            levels.push(next);
        }
        SubproductTree { levels, n }
    }

    /// The full product `M(x) = Π (x − x_i)`.
    pub fn root(&self) -> &Vec<R::Elem> {
        &self.levels.last().unwrap()[0]
    }

    /// Going-down remainder evaluation: `f mod` each node, leaves give
    /// `f(x_i)`.
    pub fn eval(&self, ring: &R, f: &[R::Elem]) -> Vec<R::Elem> {
        // rems for the current level, top-down
        let top = poly::divrem(ring, f, self.root()).1;
        let mut rems: Vec<Vec<R::Elem>> = vec![top];
        for level_idx in (0..self.levels.len() - 1).rev() {
            let level = &self.levels[level_idx];
            let mut next: Vec<Vec<R::Elem>> = Vec::with_capacity(level.len());
            for (k, node) in level.iter().enumerate() {
                let parent = &rems[k / 2];
                next.push(poly::divrem(ring, parent, node).1);
            }
            rems = next;
        }
        rems.into_iter()
            .map(|r| {
                if r.is_empty() {
                    ring.zero()
                } else {
                    r[0].clone()
                }
            })
            .collect()
    }

    /// Linear combination up the tree: given per-leaf constants `c_i`,
    /// computes `Σ_i c_i · Π_{j≠i} (x − x_j)`.
    pub fn combine(&self, ring: &R, cs: &[R::Elem]) -> Vec<R::Elem> {
        assert_eq!(cs.len(), self.n);
        let mut polys: Vec<Vec<R::Elem>> = cs
            .iter()
            .map(|c| {
                if ring.is_zero(c) {
                    vec![]
                } else {
                    vec![c.clone()]
                }
            })
            .collect();
        for level_idx in 0..self.levels.len() - 1 {
            let level = &self.levels[level_idx];
            let mut next: Vec<Vec<R::Elem>> = Vec::with_capacity(level.len().div_ceil(2));
            let mut k = 0;
            while k < level.len() {
                if k + 1 < level.len() {
                    // left * right_subproduct + right * left_subproduct
                    let l = poly::mul(ring, &polys[k], &level[k + 1]);
                    let r = poly::mul(ring, &polys[k + 1], &level[k]);
                    next.push(poly::add(ring, &l, &r));
                } else {
                    next.push(polys[k].clone());
                }
                k += 2;
            }
            polys = next;
        }
        polys.pop().unwrap()
    }
}

/// Fast multipoint evaluation, `O(n log² n)`.
pub fn eval_many_fast<R: Ring>(ring: &R, f: &[R::Elem], pts: &[R::Elem]) -> Vec<R::Elem> {
    let tree = SubproductTree::build(ring, pts);
    tree.eval(ring, f)
}

/// Lagrange denominators `λ_i = Π_{j≠i} (x_i − x_j)^{-1}` (all units on an
/// exceptional sequence).
pub fn lagrange_denominators<R: Ring>(ring: &R, pts: &[R::Elem]) -> Vec<R::Elem> {
    let n = pts.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut prod = ring.one();
        for j in 0..n {
            if i != j {
                let d = ring.sub(&pts[i], &pts[j]);
                prod = ring.mul(&prod, &d);
            }
        }
        out.push(
            ring.inv(&prod)
                .expect("points must form an exceptional sequence"),
        );
    }
    out
}

/// Coefficient vectors of the Lagrange basis polynomials `L_i(x)`
/// (`L_i(x_j) = δ_ij`, `deg L_i = n−1`). `O(n²)`.
///
/// Column stacking of these vectors is the inverse of the Vandermonde matrix
/// on `pts`; the decoders consume selected *rows* of that inverse as decode
/// weights (see `codes::ep`).
pub fn lagrange_basis_coeffs<R: Ring>(ring: &R, pts: &[R::Elem]) -> Vec<Vec<R::Elem>> {
    let n = pts.len();
    let m = poly::from_roots(ring, pts);
    let lambdas = lagrange_denominators(ring, pts);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // M(x) / (x − x_i) by synthetic division: O(n)
        let mut q = vec![ring.zero(); n];
        let mut carry = ring.zero();
        for k in (0..n).rev() {
            // q_k = m_{k+1} + x_i * q_{k+1}
            let qk = ring.add(&m[k + 1], &ring.mul(&pts[i], &carry));
            q[k] = qk.clone();
            carry = qk;
        }
        out.push(poly::scale(ring, &q, &lambdas[i]));
    }
    out
}

/// Naive Lagrange interpolation: the unique `f` with `deg f < n` and
/// `f(x_i) = y_i`. `O(n²)`.
pub fn interpolate_naive<R: Ring>(ring: &R, pts: &[R::Elem], ys: &[R::Elem]) -> Vec<R::Elem> {
    assert_eq!(pts.len(), ys.len());
    let basis = lagrange_basis_coeffs(ring, pts);
    let mut acc = vec![ring.zero(); pts.len()];
    for (li, y) in basis.iter().zip(ys) {
        if ring.is_zero(y) {
            continue;
        }
        for (k, c) in li.iter().enumerate() {
            ring.mul_add_assign(&mut acc[k], c, y);
        }
    }
    poly::trim(ring, acc)
}

/// Fast interpolation via the subproduct tree, `O(n log² n)`:
/// `f = Σ y_i / M'(x_i) · M(x)/(x − x_i)` computed by tree combination.
pub fn interpolate_fast<R: Ring>(ring: &R, pts: &[R::Elem], ys: &[R::Elem]) -> Vec<R::Elem> {
    assert_eq!(pts.len(), ys.len());
    let tree = SubproductTree::build(ring, pts);
    let mprime = poly::derivative(ring, tree.root());
    let denom = tree.eval(ring, &mprime); // M'(x_i) = Π_{j≠i}(x_i − x_j)
    let cs: Vec<R::Elem> = ys
        .iter()
        .zip(&denom)
        .map(|(y, d)| {
            let dinv = ring
                .inv(d)
                .expect("points must form an exceptional sequence");
            ring.mul(y, &dinv)
        })
        .collect();
    poly::trim(ring, tree.combine(ring, &cs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;
    use crate::ring::Ring;
    use crate::util::rng::Rng64;

    #[test]
    fn naive_vs_fast_eval_z2e() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let mut rng = Rng64::seeded(41);
        let pts = ring.exceptional_points(8).unwrap();
        for degree in [0usize, 1, 3, 7, 12] {
            let f: Vec<_> = (0..=degree).map(|_| ring.random(&mut rng)).collect();
            assert_eq!(
                eval_many_naive(&ring, &f, &pts),
                eval_many_fast(&ring, &f, &pts),
                "degree {degree}"
            );
        }
    }

    #[test]
    fn interpolation_roundtrip_naive() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let mut rng = Rng64::seeded(42);
        let pts = ring.exceptional_points(9).unwrap();
        let f: Vec<_> = (0..9).map(|_| ring.random(&mut rng)).collect();
        let ys = eval_many_naive(&ring, &f, &pts);
        let g = interpolate_naive(&ring, &pts, &ys);
        assert_eq!(poly::trim(&ring, f), g);
    }

    #[test]
    fn interpolation_roundtrip_fast() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let mut rng = Rng64::seeded(43);
        let pts = ring.exceptional_points(11).unwrap();
        let f: Vec<_> = (0..11).map(|_| ring.random(&mut rng)).collect();
        let ys = eval_many_fast(&ring, &f, &pts);
        let g = interpolate_fast(&ring, &pts, &ys);
        assert_eq!(poly::trim(&ring, f), g);
    }

    #[test]
    fn naive_and_fast_interpolation_agree() {
        let ring = Extension::new(Zq::z2e(32), 3);
        let mut rng = Rng64::seeded(44);
        let pts = ring.exceptional_points(7).unwrap();
        let ys: Vec<_> = (0..7).map(|_| ring.random(&mut rng)).collect();
        assert_eq!(
            interpolate_naive(&ring, &pts, &ys),
            interpolate_fast(&ring, &pts, &ys)
        );
    }

    #[test]
    fn lagrange_basis_kronecker_delta() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let pts = ring.exceptional_points(6).unwrap();
        let basis = lagrange_basis_coeffs(&ring, &pts);
        for (i, li) in basis.iter().enumerate() {
            for (j, x) in pts.iter().enumerate() {
                let v = poly::eval(&ring, li, x);
                if i == j {
                    assert_eq!(v, ring.one());
                } else {
                    assert!(ring.is_zero(&v));
                }
            }
        }
    }

    #[test]
    fn interpolation_over_odd_char() {
        let ring = Zq::new(17, 2); // Z_289; 17 exceptional points available
        let mut rng = Rng64::seeded(45);
        let pts = ring.exceptional_points(10).unwrap();
        let f: Vec<_> = (0..10).map(|_| ring.random(&mut rng)).collect();
        let ys = eval_many_naive(&ring, &f, &pts);
        assert_eq!(
            interpolate_fast(&ring, &pts, &ys),
            poly::trim(&ring, f)
        );
    }

    #[test]
    fn tree_root_is_full_product() {
        let ring = Zq::new(13, 1);
        let pts = ring.exceptional_points(5).unwrap();
        let tree = SubproductTree::build(&ring, &pts);
        assert_eq!(tree.root(), &poly::from_roots(&ring, &pts));
    }

    #[test]
    fn non_power_of_two_points() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let mut rng = Rng64::seeded(46);
        for n in [1usize, 2, 3, 5, 6, 7, 9, 13] {
            let pts = ring.exceptional_points(n).unwrap();
            let f: Vec<_> = (0..n).map(|_| ring.random(&mut rng)).collect();
            let ys = eval_many_fast(&ring, &f, &pts);
            assert_eq!(
                interpolate_fast(&ring, &pts, &ys),
                poly::trim(&ring, f.clone()),
                "n = {n}"
            );
        }
    }
}
