//! Cross-module integration: every scheme, end to end, over multiple rings,
//! shapes and responder subsets — beyond the per-module unit tests. All
//! schemes run through the one `DmmScheme` trait with plane-major shares.

use gr_cdmm::codes::batch_ep_rmfe::BatchEpRmfe;
use gr_cdmm::codes::csa::CsaCode;
use gr_cdmm::codes::ep::{EpCode, PlainEp};
use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::ep_rmfe_ii::EpRmfeII;
use gr_cdmm::codes::matdot::MatDotCode;
use gr_cdmm::codes::polynomial::PolynomialCode;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::galois::GaloisRing;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::traits::Ring;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;

/// Generic single-scheme roundtrip with a random responder subset.
fn single_roundtrip<R: Ring, S: DmmScheme<R>>(
    scheme: &S,
    t: usize,
    r: usize,
    s: usize,
    seed: u64,
) {
    let ring = scheme.input_ring().clone();
    let mut rng = Rng64::seeded(seed);
    let a = Matrix::random(&ring, t, r, &mut rng);
    let b = Matrix::random(&ring, r, s, &mut rng);
    let shares = scheme.encode(&a, &b).unwrap();
    let picks = rng.choose_k(scheme.n_workers(), scheme.recovery_threshold());
    let responses: Vec<_> = picks
        .iter()
        .map(|&i| (i, scheme.worker_compute(&shares[i]).unwrap()))
        .collect();
    let c = scheme.decode(&responses).unwrap();
    assert_eq!(c, Matrix::matmul(&ring, &a, &b), "{}", scheme.name());
}

#[test]
fn all_single_schemes_random_subsets() {
    let base = Zq::z2e(64);
    for seed in 0..5u64 {
        single_roundtrip(
            &PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap(),
            8, 8, 8, 300 + seed,
        );
        single_roundtrip(
            &EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap(),
            8, 8, 8, 310 + seed,
        );
        single_roundtrip(
            &EpRmfeII::new(base.clone(), 8, 2, 1, 2, 2).unwrap(),
            8, 8, 8, 320 + seed,
        );
    }
}

#[test]
fn all_single_schemes_16_workers() {
    let base = Zq::z2e(64);
    single_roundtrip(&PlainEp::new(base.clone(), 16, 2, 2, 2).unwrap(), 8, 8, 8, 330);
    single_roundtrip(&EpRmfeI::new(base.clone(), 16, 2, 2, 2, 2).unwrap(), 8, 8, 8, 331);
    single_roundtrip(&EpRmfeII::new(base.clone(), 16, 2, 2, 2, 2).unwrap(), 8, 8, 8, 332);
}

#[test]
fn direct_codes_over_extension_rings() {
    let ext3 = Extension::new(Zq::z2e(64), 3);
    single_roundtrip(&EpCode::new(ext3.clone(), 8, 2, 1, 2).unwrap(), 4, 4, 4, 340);
    single_roundtrip(&PolynomialCode::new(ext3.clone(), 8, 2, 2).unwrap(), 4, 4, 4, 341);
    single_roundtrip(&MatDotCode::new(ext3, 8, 3).unwrap(), 4, 6, 4, 342);
}

#[test]
fn schemes_over_odd_characteristic() {
    // Z_{3^5}: 3 exceptional points in the base; m covers N.
    let base = Zq::new(3, 5);
    single_roundtrip(&PlainEp::new(base.clone(), 10, 2, 1, 2).unwrap(), 4, 4, 4, 350);
    single_roundtrip(&EpRmfeI::new(base.clone(), 10, 2, 1, 2, 2).unwrap(), 4, 4, 4, 351);
    single_roundtrip(&EpRmfeII::new(base, 10, 2, 1, 2, 3).unwrap(), 4, 4, 6, 352);
}

#[test]
fn schemes_over_small_galois_field() {
    // GF(4) inputs — the paper's "small Galois field" contribution.
    let base = GaloisRing::new(2, 1, 2);
    single_roundtrip(&PlainEp::new(base.clone(), 17, 2, 2, 2).unwrap(), 4, 4, 4, 360);
    single_roundtrip(&EpRmfeI::new(base.clone(), 17, 2, 2, 2, 2).unwrap(), 4, 4, 4, 361);
}

#[test]
fn batch_schemes_roundtrip_many_configs() {
    let base = Zq::z2e(64);
    for (n_batch, n_workers, u, w, v) in [(2, 8, 2, 1, 2), (2, 16, 2, 2, 2), (3, 32, 2, 1, 2)] {
        let scheme = BatchEpRmfe::new(base.clone(), n_workers, n_batch, u, w, v).unwrap();
        let mut rng = Rng64::seeded(370 + n_workers as u64);
        let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let shares = scheme.encode_batch(&a, &b).unwrap();
        let picks = rng.choose_k(n_workers, scheme.recovery_threshold());
        let responses: Vec<_> = picks
            .iter()
            .map(|&i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = scheme.decode_batch(&responses).unwrap();
        for k in 0..n_batch {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
        }
    }
}

#[test]
fn csa_random_subsets() {
    let ext = Extension::new(Zq::z2e(64), 4);
    let csa = CsaCode::new(ext.clone(), 9, 3).unwrap();
    let mut rng = Rng64::seeded(380);
    let a: Vec<_> = (0..3).map(|_| Matrix::random(&ext, 3, 3, &mut rng)).collect();
    let b: Vec<_> = (0..3).map(|_| Matrix::random(&ext, 3, 3, &mut rng)).collect();
    let shares = csa.encode_batch(&a, &b).unwrap();
    for trial in 0..4 {
        let picks = rng.choose_k(9, csa.recovery_threshold());
        let responses: Vec<_> = picks
            .iter()
            .map(|&i| (i, csa.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = csa.decode_batch(&responses).unwrap();
        for k in 0..3 {
            assert_eq!(c[k], Matrix::matmul(&ext, &a[k], &b[k]), "trial {trial}");
        }
    }
}

#[test]
fn comm_model_matches_wire_for_all_schemes() {
    let base = Zq::z2e(64);
    let (t, r, s) = (8usize, 8, 8);
    let mut rng = Rng64::seeded(390);
    let a = Matrix::random(&base, t, r, &mut rng);
    let b = Matrix::random(&base, r, s, &mut rng);

    macro_rules! check {
        ($scheme:expr) => {{
            let scheme = $scheme;
            let shares = scheme.encode(&a, &b).unwrap();
            let ring = scheme.share_ring();
            let wire: usize = shares.iter().map(|sh| sh.byte_len(ring)).sum();
            assert_eq!(wire, scheme.upload_bytes(t, r, s), "{}", scheme.name());
            let resp = scheme.worker_compute(&shares[0]).unwrap();
            assert_eq!(
                resp.byte_len(ring) * scheme.recovery_threshold(),
                scheme.download_bytes(t, r, s),
                "{}",
                scheme.name()
            );
        }};
    }
    check!(PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap());
    check!(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    check!(EpRmfeII::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
}
