//! Cross-module integration: every scheme, end to end, over multiple rings,
//! shapes and responder subsets — beyond the per-module unit tests. All
//! schemes run through the one `DmmScheme` trait with plane-major shares.

use gr_cdmm::codes::batch_ep_rmfe::BatchEpRmfe;
use gr_cdmm::codes::csa::CsaCode;
use gr_cdmm::codes::ep::{EpCode, PlainEp};
use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::ep_rmfe_ii::EpRmfeII;
use gr_cdmm::codes::matdot::MatDotCode;
use gr_cdmm::codes::polynomial::PolynomialCode;
use gr_cdmm::codes::registry::{self, SchemeConfig, SCHEME_NAMES};
use gr_cdmm::codes::scheme::{DmmScheme, DynScheme};
use gr_cdmm::codes::secure_matdot::SecureMatDot;
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::galois::GaloisRing;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::scalar_table_builds;
use gr_cdmm::ring::traits::Ring;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::parallel::with_threads;
use gr_cdmm::util::rng::Rng64;

/// Generic single-scheme roundtrip with a random responder subset.
fn single_roundtrip<R: Ring, S: DmmScheme<R>>(
    scheme: &S,
    t: usize,
    r: usize,
    s: usize,
    seed: u64,
) {
    let ring = scheme.input_ring().clone();
    let mut rng = Rng64::seeded(seed);
    let a = Matrix::random(&ring, t, r, &mut rng);
    let b = Matrix::random(&ring, r, s, &mut rng);
    let shares = scheme.encode(&a, &b).unwrap();
    let picks = rng.choose_k(scheme.n_workers(), scheme.recovery_threshold());
    let responses: Vec<_> = picks
        .iter()
        .map(|&i| (i, scheme.worker_compute(&shares[i]).unwrap()))
        .collect();
    let c = scheme.decode(&responses).unwrap();
    assert_eq!(c, Matrix::matmul(&ring, &a, &b), "{}", scheme.name());
}

#[test]
fn all_single_schemes_random_subsets() {
    let base = Zq::z2e(64);
    for seed in 0..5u64 {
        single_roundtrip(
            &PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap(),
            8, 8, 8, 300 + seed,
        );
        single_roundtrip(
            &EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap(),
            8, 8, 8, 310 + seed,
        );
        single_roundtrip(
            &EpRmfeII::new(base.clone(), 8, 2, 1, 2, 2).unwrap(),
            8, 8, 8, 320 + seed,
        );
    }
}

#[test]
fn all_single_schemes_16_workers() {
    let base = Zq::z2e(64);
    single_roundtrip(&PlainEp::new(base.clone(), 16, 2, 2, 2).unwrap(), 8, 8, 8, 330);
    single_roundtrip(&EpRmfeI::new(base.clone(), 16, 2, 2, 2, 2).unwrap(), 8, 8, 8, 331);
    single_roundtrip(&EpRmfeII::new(base.clone(), 16, 2, 2, 2, 2).unwrap(), 8, 8, 8, 332);
}

#[test]
fn direct_codes_over_extension_rings() {
    let ext3 = Extension::new(Zq::z2e(64), 3);
    single_roundtrip(&EpCode::new(ext3.clone(), 8, 2, 1, 2).unwrap(), 4, 4, 4, 340);
    single_roundtrip(&PolynomialCode::new(ext3.clone(), 8, 2, 2).unwrap(), 4, 4, 4, 341);
    single_roundtrip(&MatDotCode::new(ext3, 8, 3).unwrap(), 4, 6, 4, 342);
}

#[test]
fn schemes_over_odd_characteristic() {
    // Z_{3^5}: 3 exceptional points in the base; m covers N.
    let base = Zq::new(3, 5);
    single_roundtrip(&PlainEp::new(base.clone(), 10, 2, 1, 2).unwrap(), 4, 4, 4, 350);
    single_roundtrip(&EpRmfeI::new(base.clone(), 10, 2, 1, 2, 2).unwrap(), 4, 4, 4, 351);
    single_roundtrip(&EpRmfeII::new(base, 10, 2, 1, 2, 3).unwrap(), 4, 4, 6, 352);
}

#[test]
fn schemes_over_small_galois_field() {
    // GF(4) inputs — the paper's "small Galois field" contribution.
    let base = GaloisRing::new(2, 1, 2);
    single_roundtrip(&PlainEp::new(base.clone(), 17, 2, 2, 2).unwrap(), 4, 4, 4, 360);
    single_roundtrip(&EpRmfeI::new(base.clone(), 17, 2, 2, 2, 2).unwrap(), 4, 4, 4, 361);
}

#[test]
fn batch_schemes_roundtrip_many_configs() {
    let base = Zq::z2e(64);
    for (n_batch, n_workers, u, w, v) in [(2, 8, 2, 1, 2), (2, 16, 2, 2, 2), (3, 32, 2, 1, 2)] {
        let scheme = BatchEpRmfe::new(base.clone(), n_workers, n_batch, u, w, v).unwrap();
        let mut rng = Rng64::seeded(370 + n_workers as u64);
        let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let shares = scheme.encode_batch(&a, &b).unwrap();
        let picks = rng.choose_k(n_workers, scheme.recovery_threshold());
        let responses: Vec<_> = picks
            .iter()
            .map(|&i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = scheme.decode_batch(&responses).unwrap();
        for k in 0..n_batch {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
        }
    }
}

#[test]
fn csa_random_subsets() {
    let ext = Extension::new(Zq::z2e(64), 4);
    let csa = CsaCode::new(ext.clone(), 9, 3).unwrap();
    let mut rng = Rng64::seeded(380);
    let a: Vec<_> = (0..3).map(|_| Matrix::random(&ext, 3, 3, &mut rng)).collect();
    let b: Vec<_> = (0..3).map(|_| Matrix::random(&ext, 3, 3, &mut rng)).collect();
    let shares = csa.encode_batch(&a, &b).unwrap();
    for trial in 0..4 {
        let picks = rng.choose_k(9, csa.recovery_threshold());
        let responses: Vec<_> = picks
            .iter()
            .map(|&i| (i, csa.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = csa.decode_batch(&responses).unwrap();
        for k in 0..3 {
            assert_eq!(c[k], Matrix::matmul(&ext, &a[k], &b[k]), "trial {trial}");
        }
    }
}

/// One full job through the byte facade on the fixed fast subset
/// `{0..R−1}`: returns everything that crosses the wire, for equality
/// comparison across thread counts.
fn byte_job(
    scheme: &dyn DynScheme,
    a: &[Vec<u8>],
    b: &[Vec<u8>],
) -> (Vec<Vec<u8>>, Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let payloads: Vec<Vec<u8>> =
        scheme.encode_bytes(a, b).unwrap().iter().map(|p| p.to_vec()).collect();
    let rt = scheme.recovery_threshold();
    let responses: Vec<Vec<u8>> =
        (0..rt).map(|i| scheme.compute_bytes(&payloads[i]).unwrap().to_vec()).collect();
    let borrowed: Vec<(usize, &[u8])> =
        responses.iter().enumerate().map(|(i, p)| (i, p.as_slice())).collect();
    let out: Vec<Vec<u8>> =
        scheme.decode_bytes(&borrowed).unwrap().iter().map(|p| p.to_vec()).collect();
    (payloads, responses, out)
}

/// Every registered scheme, end to end through the byte facade, must be
/// **bit-identical at every thread count** — share payloads, worker
/// responses and decoded outputs — and correct against the local product.
#[test]
fn registry_schemes_thread_count_invariant_end_to_end() {
    let base = Zq::z2e(64);
    let cfg = SchemeConfig::for_workers(8).unwrap();
    for (name, _) in SCHEME_NAMES {
        let scheme = registry::build(name, &cfg).unwrap();
        let n = scheme.batch_size();
        // 32² inputs sit above the parallel work floors (MIN_PAR_OPS), so
        // the threaded encode/decode fan-outs genuinely engage at t >= 2.
        let mut rng = Rng64::seeded(900);
        let a: Vec<Matrix<u64>> =
            (0..n).map(|_| Matrix::random(&base, 32, 32, &mut rng)).collect();
        let b: Vec<Matrix<u64>> =
            (0..n).map(|_| Matrix::random(&base, 32, 32, &mut rng)).collect();
        let ab: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(&base)).collect();
        let bb: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(&base)).collect();
        let reference = with_threads(1, || byte_job(scheme.as_ref(), &ab, &bb));
        for t in [2usize, 8] {
            let got = with_threads(t, || byte_job(scheme.as_ref(), &ab, &bb));
            assert_eq!(got, reference, "{name} at {t} threads diverged from sequential");
        }
        for (k, buf) in reference.2.iter().enumerate() {
            let c = Matrix::from_bytes(&base, buf).unwrap();
            assert_eq!(c, Matrix::matmul(&base, &a[k], &b[k]), "{name} slot {k}");
        }
    }
}

/// The acceptance probe for the encode/decode plans: after one cold job
/// (which may build tables — scheme construction and the first decode plan
/// for a subset do), further jobs on the same responding subset build
/// **zero** scalar-mul tables anywhere in encode, worker compute or
/// decode. Run single-threaded so the per-thread build counter sees every
/// build.
#[test]
fn steady_state_jobs_build_zero_scalar_tables() {
    let base = Zq::z2e(64);
    let cfg = SchemeConfig::for_workers(8).unwrap();
    with_threads(1, || {
        for (name, _) in SCHEME_NAMES {
            let scheme = registry::build(name, &cfg).unwrap();
            let n = scheme.batch_size();
            let mut rng = Rng64::seeded(910);
            let job = |rng: &mut Rng64| {
                let a: Vec<Vec<u8>> = (0..n)
                    .map(|_| Matrix::random(&base, 8, 8, rng).to_bytes(&base))
                    .collect();
                let b: Vec<Vec<u8>> = (0..n)
                    .map(|_| Matrix::random(&base, 8, 8, rng).to_bytes(&base))
                    .collect();
                byte_job(scheme.as_ref(), &a, &b)
            };
            job(&mut rng); // cold: warms the {0..R−1} decode plan
            let before = scalar_table_builds();
            job(&mut rng);
            job(&mut rng);
            assert_eq!(
                scalar_table_builds(),
                before,
                "{name}: steady-state encode/compute/decode must build no scalar-mul tables"
            );
        }
        // the typed secure-MatDot path too (not in the registry)
        let ring = Extension::new(Zq::z2e(64), 3);
        let code = SecureMatDot::new(ring.clone(), 5, 1, 1, 911).unwrap();
        let mut rng = Rng64::seeded(912);
        let job = |rng: &mut Rng64| {
            let a = Matrix::random(&ring, 4, 4, rng);
            let b = Matrix::random(&ring, 4, 4, rng);
            let shares = code.encode(&a, &b).unwrap();
            let responses: Vec<_> = (0..code.recovery_threshold())
                .map(|i| (i, code.worker_compute(&shares[i]).unwrap()))
                .collect();
            assert_eq!(code.decode(&responses).unwrap(), Matrix::matmul(&ring, &a, &b));
        };
        job(&mut rng);
        let before = scalar_table_builds();
        job(&mut rng);
        assert_eq!(scalar_table_builds(), before, "secure-matdot steady state");
    });
}

#[test]
fn comm_model_matches_wire_for_all_schemes() {
    let base = Zq::z2e(64);
    let (t, r, s) = (8usize, 8, 8);
    let mut rng = Rng64::seeded(390);
    let a = Matrix::random(&base, t, r, &mut rng);
    let b = Matrix::random(&base, r, s, &mut rng);

    macro_rules! check {
        ($scheme:expr) => {{
            let scheme = $scheme;
            let shares = scheme.encode(&a, &b).unwrap();
            let ring = scheme.share_ring();
            let wire: usize = shares.iter().map(|sh| sh.byte_len(ring)).sum();
            assert_eq!(wire, scheme.upload_bytes(t, r, s), "{}", scheme.name());
            let resp = scheme.worker_compute(&shares[0]).unwrap();
            assert_eq!(
                resp.byte_len(ring) * scheme.recovery_threshold(),
                scheme.download_bytes(t, r, s),
                "{}",
                scheme.name()
            );
        }};
    }
    check!(PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap());
    check!(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    check!(EpRmfeII::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
}
