//! Property-based tests: randomized invariants across the whole stack,
//! driven by an in-repo case generator (the offline crate cache has no
//! proptest; seeds are deterministic so failures reproduce exactly).

use gr_cdmm::codes::batch_ep_rmfe::BatchEpRmfe;
use gr_cdmm::codes::csa::CsaCode;
use gr_cdmm::codes::ep::EpCode;
use gr_cdmm::codes::scheme::{DmmScheme, Share};
use gr_cdmm::ring::arch::{available_backends, with_backend};
use gr_cdmm::ring::eval::{
    eval_many_fast, eval_many_naive, interpolate_fast, interpolate_naive,
};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::galois::GaloisRing;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::{
    slice_matmul_acc, slice_matmul_acc_threads, PlaneMatrix, PlaneRing, ScalarTable,
};
use gr_cdmm::ring::poly;
use gr_cdmm::ring::traits::{is_exceptional_sequence, Ring};
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::rmfe::{PolyRmfe, RmfeScheme};
use gr_cdmm::util::parallel::with_threads;
use gr_cdmm::util::rng::Rng64;

const CASES: usize = 40;

/// Property: ring axioms hold for random elements of random rings.
#[test]
fn prop_ring_axioms() {
    let mut seeder = Rng64::seeded(1000);
    for case in 0..CASES {
        let mut rng = seeder.fork();
        let which = case % 4;
        macro_rules! axioms {
            ($ring:expr) => {{
                let ring = $ring;
                let a = ring.random(&mut rng);
                let b = ring.random(&mut rng);
                let c = ring.random(&mut rng);
                assert_eq!(ring.add(&a, &b), ring.add(&b, &a));
                assert_eq!(ring.mul(&a, &b), ring.mul(&b, &a));
                assert_eq!(
                    ring.mul(&ring.mul(&a, &b), &c),
                    ring.mul(&a, &ring.mul(&b, &c))
                );
                assert_eq!(
                    ring.mul(&a, &ring.add(&b, &c)),
                    ring.add(&ring.mul(&a, &b), &ring.mul(&a, &c))
                );
                assert_eq!(ring.sub(&a, &a), ring.zero());
                if ring.is_unit(&a) {
                    let inv = ring.inv(&a).unwrap();
                    assert_eq!(ring.mul(&a, &inv), ring.one());
                }
            }};
        }
        match which {
            0 => axioms!(Zq::z2e(1 + (case as u32 * 7) % 64)),
            1 => axioms!(Zq::new([3, 5, 7, 11][case % 4], 1 + (case as u32) % 5)),
            2 => axioms!(GaloisRing::new(2, 32, 1 + case % 5)),
            _ => axioms!(Extension::new(Zq::z2e(64), 1 + case % 5)),
        }
    }
}

/// Property: exceptional sequences really are exceptional, at max size.
#[test]
fn prop_exceptional_sets() {
    for (p, e, d) in [(2u64, 64u32, 1usize), (2, 8, 3), (3, 3, 2), (5, 2, 1)] {
        let ring = GaloisRing::new(p, e, d);
        let max = ring.residue_size().min(64) as usize;
        let pts = ring.exceptional_points(max).unwrap();
        assert!(is_exceptional_sequence(&ring, &pts), "GR({p}^{e},{d})");
    }
}

/// Property: divrem reconstructs, eval/interp invert each other, naive and
/// fast algorithms agree — over random rings and degrees.
#[test]
fn prop_poly_eval_interp() {
    let mut seeder = Rng64::seeded(2000);
    for case in 0..CASES {
        let mut rng = seeder.fork();
        let m = 3 + case % 3;
        let ring = Extension::new(Zq::z2e(64), m);
        let max_pts = (1usize << m).min(14);
        let n = 2 + case % (max_pts - 1);
        let pts = ring.exceptional_points(n).unwrap();
        let f: Vec<_> = (0..n).map(|_| ring.random(&mut rng)).collect();
        let f = poly::trim(&ring, f);
        let naive = eval_many_naive(&ring, &f, &pts);
        let fast = eval_many_fast(&ring, &f, &pts);
        assert_eq!(naive, fast, "case {case}");
        let gi = interpolate_naive(&ring, &pts, &naive);
        let gf = interpolate_fast(&ring, &pts, &naive);
        assert_eq!(gi, gf, "case {case}");
        assert_eq!(gi, f, "case {case}");
    }
}

/// Property: RMFE product law over random bases, n and padding m.
#[test]
fn prop_rmfe_product_law() {
    let mut seeder = Rng64::seeded(3000);
    for case in 0..CASES {
        let mut rng = seeder.fork();
        // random (n, m ≥ 2n−1) over Z_2^64 (n ≤ 3) or GR(2^16,2) (n ≤ 5)
        let (rmfe, n) = if case % 2 == 0 {
            let n = 2 + case % 2;
            (PolyRmfe::with_m(Zq::z2e(64), n, 2 * n - 1 + case % 3).unwrap(), n)
        } else {
            let n = 2 + case % 4;
            // base GR(2^16, 2) exposed via Zq? use Zq::new(2,16) ext of GaloisRing not needed:
            (PolyRmfe::with_m(Zq::z2e(16), n.min(3), 2 * n.min(3) - 1).unwrap(), n.min(3))
        };
        let base = rmfe.base().clone();
        let ext = rmfe.ext().clone();
        let xs: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
        let ys: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
        let prod = ext.mul(&rmfe.phi(&xs), &rmfe.phi(&ys));
        let got = rmfe.psi(&prod);
        let want: Vec<_> = xs.iter().zip(&ys).map(|(x, y)| base.mul(x, y)).collect();
        assert_eq!(got, want, "case {case}");
    }
}

/// Property: EP decode is invariant to WHICH R-subset responds and to
/// permutation of the responses.
#[test]
fn prop_ep_subset_invariance() {
    let mut seeder = Rng64::seeded(4000);
    let ring = Extension::new(Zq::z2e(64), 4);
    let ep = EpCode::new(ring.clone(), 12, 2, 2, 2).unwrap();
    let mut rng = seeder.fork();
    let a = Matrix::random(&ring, 4, 4, &mut rng);
    let b = Matrix::random(&ring, 4, 4, &mut rng);
    let expected = Matrix::matmul(&ring, &a, &b);
    let shares = ep.encode(&a, &b).unwrap();
    let all: Vec<_> = shares
        .iter()
        .enumerate()
        .map(|(i, s)| (i, ep.worker_compute(s).unwrap()))
        .collect();
    for case in 0..20 {
        let mut rng = seeder.fork();
        let mut picks = rng.choose_k(12, ep.recovery_threshold());
        rng.shuffle(&mut picks);
        let responses: Vec<_> = picks.iter().map(|&i| all[i].clone()).collect();
        assert_eq!(ep.decode(&responses).unwrap(), expected, "case {case}");
    }
}

/// Property: Batch-EP_RMFE equals n independent local products for random
/// batch shapes.
#[test]
fn prop_batch_matches_local() {
    let mut seeder = Rng64::seeded(5000);
    for case in 0..12 {
        let mut rng = seeder.fork();
        let base = Zq::z2e(64);
        let scheme = BatchEpRmfe::new(base.clone(), 8, 2, 2, 1, 2).unwrap();
        let t = 2 * (1 + case % 3);
        let r = 1 + case % 4;
        let s = 2 * (1 + case % 2);
        let a: Vec<_> = (0..2).map(|_| Matrix::random(&base, t, r, &mut rng)).collect();
        let b: Vec<_> = (0..2).map(|_| Matrix::random(&base, r, s, &mut rng)).collect();
        let shares = scheme.encode_batch(&a, &b).unwrap();
        let responses: Vec<_> = (0..scheme.recovery_threshold())
            .map(|i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = scheme.decode_batch(&responses).unwrap();
        for k in 0..2 {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]), "case {case}");
        }
    }
}

/// Property: matrix serialization roundtrips for random shapes and rings.
#[test]
fn prop_serialization_roundtrip() {
    let mut seeder = Rng64::seeded(6000);
    for case in 0..CASES {
        let mut rng = seeder.fork();
        let m = 1 + case % 5;
        let ring = Extension::new(Zq::z2e(64), m);
        let rows = 1 + rng.below_usize(6);
        let cols = 1 + rng.below_usize(6);
        let mat = Matrix::random(&ring, rows, cols, &mut rng);
        let bytes = mat.to_bytes(&ring);
        assert_eq!(bytes.len(), mat.byte_len(&ring));
        assert_eq!(Matrix::from_bytes(&ring, &bytes).unwrap(), mat, "case {case}");
    }
}

/// Property: plane-major serialization roundtrips (matrix and share level)
/// across `Zq`, `GaloisRing` and `Extension` towers, and truncations of any
/// length are rejected as clean errors.
#[test]
fn prop_plane_serialization_roundtrip() {
    fn check<E: PlaneRing>(ring: &E, seed: u64) {
        let mut rng = Rng64::seeded(seed);
        for case in 0..10 {
            let rows = 1 + rng.below_usize(5);
            let cols = 1 + rng.below_usize(5);
            let mat = PlaneMatrix::random(ring, rows, cols, &mut rng);
            let bytes = mat.to_bytes(ring);
            assert_eq!(bytes.len(), mat.byte_len(ring), "{} case {case}", ring.name());
            assert_eq!(
                PlaneMatrix::from_bytes(ring, &bytes).unwrap(),
                mat,
                "{} case {case}",
                ring.name()
            );
            // every strict prefix fails cleanly
            let cut = rng.below_usize(bytes.len());
            assert!(
                PlaneMatrix::<E::Base>::from_bytes(ring, &bytes[..cut]).is_err(),
                "{} case {case}: prefix of {cut} bytes must be rejected",
                ring.name()
            );
            // share-level roundtrip (a |> b as one contiguous block)
            let share: Share<E> = Share {
                a: mat.clone(),
                b: PlaneMatrix::random(ring, cols, rows, &mut rng),
            };
            let sb = share.to_bytes(ring);
            assert_eq!(sb.len(), share.byte_len(ring));
            assert_eq!(Share::from_bytes(ring, &sb).unwrap(), share);
            assert!(Share::<E>::from_bytes(ring, &sb[..sb.len() - 1]).is_err());
        }
    }
    check(&Zq::z2e(64), 6100);
    check(&Zq::new(3, 5), 6101);
    check(&GaloisRing::new(2, 16, 2), 6102);
    check(&Extension::new(Zq::z2e(64), 3), 6103);
    check(&Extension::new(Zq::z2e(64), 5), 6104);
    check(&Extension::new(GaloisRing::new(2, 16, 2), 2), 6105);
}

/// Property: the plane-major matmul kernel is bit-identical to the AoS
/// extension matmul on random inputs for every Table 1 / §V.A parameter set
/// (m = 3, 4, 5 over Z_2^64 and the GR(2^16,2) tower base), plus the axpy
/// used by encode/decode.
#[test]
fn prop_plane_matmul_equals_aos() {
    let mut seeder = Rng64::seeded(6200);
    for m in [3usize, 4, 5] {
        let ext = Extension::new(Zq::z2e(64), m);
        for case in 0..8 {
            let mut rng = seeder.fork();
            let (t, r, s) = (1 + case % 4, 1 + (case + 1) % 4, 1 + (case + 2) % 4);
            let a = Matrix::random(&ext, t, r, &mut rng);
            let b = Matrix::random(&ext, r, s, &mut rng);
            let pc = PlaneMatrix::matmul(
                &ext,
                &PlaneMatrix::from_aos(&ext, &a),
                &PlaneMatrix::from_aos(&ext, &b),
            );
            assert_eq!(pc.to_aos(&ext), Matrix::matmul(&ext, &a, &b), "m={m} case {case}");
            // axpy equivalence
            let x = Matrix::random(&ext, t, r, &mut rng);
            let sc = ext.random(&mut rng);
            let mut aos = a.clone();
            aos.axpy(&ext, &sc, &x);
            let mut pla = PlaneMatrix::from_aos(&ext, &a);
            pla.axpy(&ext, &sc, &PlaneMatrix::from_aos(&ext, &x));
            assert_eq!(pla.to_aos(&ext), aos, "m={m} case {case} axpy");
        }
    }
    // tower over a Galois-ring base (the paper's GR(2^e, d) generality)
    let ext = Extension::new(GaloisRing::new(2, 16, 2), 2);
    let mut rng = seeder.fork();
    let a = Matrix::random(&ext, 3, 2, &mut rng);
    let b = Matrix::random(&ext, 2, 3, &mut rng);
    let pc = PlaneMatrix::matmul(
        &ext,
        &PlaneMatrix::from_aos(&ext, &a),
        &PlaneMatrix::from_aos(&ext, &b),
    );
    assert_eq!(pc.to_aos(&ext), Matrix::matmul(&ext, &a, &b));
}

/// Property: the scoped-thread plane matmul is **bit-identical** to the
/// exact sequential kernel across thread counts, for every ring tower the
/// schemes use (`Zq`, `GaloisRing`, `Extension<Zq>` at the Table-1 degrees,
/// `Extension<GaloisRing>`). Sizes sit above `MIN_PAR_OPS` so the parallel
/// path genuinely engages.
#[test]
fn prop_parallel_matmul_bit_identical_across_threads() {
    fn check<E: PlaneRing>(ring: &E, rows: usize, inner: usize, cols: usize, seed: u64) {
        let mut rng = Rng64::seeded(seed);
        let a = PlaneMatrix::random(ring, rows, inner, &mut rng);
        let b = PlaneMatrix::random(ring, inner, cols, &mut rng);
        let seq = PlaneMatrix::matmul_threads(ring, &a, &b, 1);
        for t in [2usize, 3, 8] {
            let par = PlaneMatrix::matmul_threads(ring, &a, &b, t);
            assert_eq!(par, seq, "{} threads={t}", ring.name());
        }
        // the env/override-driven default entry point agrees too
        for t in [1usize, 2, 8] {
            assert_eq!(
                with_threads(t, || PlaneMatrix::matmul(ring, &a, &b)),
                seq,
                "{} with_threads({t})",
                ring.name()
            );
        }
    }
    check(&Zq::z2e(64), 64, 40, 40, 11000);
    check(&GaloisRing::new(2, 16, 2), 40, 24, 36, 11001);
    check(&Extension::new(Zq::z2e(64), 3), 24, 20, 24, 11002);
    check(&Extension::new(Zq::z2e(64), 4), 20, 16, 20, 11003);
    check(&Extension::new(Zq::z2e(64), 5), 16, 12, 16, 11004);
    check(&Extension::new(GaloisRing::new(2, 16, 2), 2), 24, 18, 24, 11005);
}

/// Property: the row-panel-parallel flat slice kernel equals the sequential
/// one for awkward (non-divisible) shapes and thread counts beyond the row
/// count.
#[test]
fn prop_parallel_slice_matmul_bit_identical() {
    let zq = Zq::z2e(64);
    let mut seeder = Rng64::seeded(11010);
    for case in 0..6 {
        let mut rng = seeder.fork();
        let (ar, ac, bc) = (40 + 7 * case, 29 + case, 31 + 3 * case);
        let a: Vec<u64> = (0..ar * ac).map(|_| zq.random(&mut rng)).collect();
        let b: Vec<u64> = (0..ac * bc).map(|_| zq.random(&mut rng)).collect();
        let mut seq = vec![0u64; ar * bc];
        slice_matmul_acc(&zq, &mut seq, &a, &b, ar, ac, bc);
        for t in [2usize, 3, 8, 128] {
            let mut par = vec![0u64; ar * bc];
            slice_matmul_acc_threads(&zq, &mut par, &a, &b, ar, ac, bc, t);
            assert_eq!(par, seq, "case {case} threads={t}");
        }
    }
}

/// Property: the table-driven axpy/scale (the plan currency) is
/// bit-identical to the build-on-the-spot path across ring towers,
/// including the zero scalar.
#[test]
fn prop_table_driven_axpy_scale_bit_identical() {
    fn check<E: PlaneRing>(ring: &E, seed: u64) {
        let base = ring.plane_base();
        let mut rng = Rng64::seeded(seed);
        for case in 0..8 {
            let rows = 1 + rng.below_usize(5);
            let cols = 1 + rng.below_usize(5);
            let acc0 = PlaneMatrix::random(ring, rows, cols, &mut rng);
            let x = PlaneMatrix::random(ring, rows, cols, &mut rng);
            let s = if case == 0 { ring.zero() } else { ring.random(&mut rng) };
            let t = ScalarTable::build(ring, &s);
            let mut a1 = acc0.clone();
            a1.axpy(ring, &s, &x);
            let mut a2 = acc0.clone();
            a2.axpy_with_table(base, &t, &x);
            assert_eq!(a1, a2, "{} case {case} axpy", ring.name());
            let mut s1 = x.clone();
            s1.scale_assign(ring, &s);
            let mut s2 = x.clone();
            s2.scale_with_table(base, &t);
            assert_eq!(s1, s2, "{} case {case} scale", ring.name());
            // semantics: scale really is elementwise ring multiplication
            let expect = x.to_aos(ring).map(|e| ring.mul(&s, e));
            assert_eq!(s2.to_aos(ring), expect, "{} case {case} scale semantics", ring.name());
        }
    }
    check(&Zq::z2e(64), 12000);
    check(&GaloisRing::new(2, 16, 2), 12001);
    check(&Extension::new(Zq::z2e(64), 3), 12002);
    check(&Extension::new(Zq::z2e(64), 5), 12003);
    check(&Extension::new(GaloisRing::new(2, 16, 2), 2), 12004);
}

/// Property: Gauss–Jordan inverse really inverts random unit-determinant
/// matrices (built as products of elementary matrices).
#[test]
fn prop_matrix_inverse() {
    let mut seeder = Rng64::seeded(7000);
    let ring = Extension::new(Zq::z2e(64), 3);
    for case in 0..15 {
        let mut rng = seeder.fork();
        let n = 2 + case % 4;
        // random invertible: identity + random elementary row operations
        let mut m = Matrix::identity(&ring, n);
        for _ in 0..3 * n {
            let i = rng.below_usize(n);
            let j = rng.below_usize(n);
            if i != j {
                let s = ring.random(&mut rng);
                for k in 0..n {
                    let t = ring.mul(&s, m.at(j, k));
                    m.set(i, k, ring.add(m.at(i, k), &t));
                }
            }
        }
        let inv = m.invert(&ring).expect("unit determinant by construction");
        let prod = Matrix::matmul(&ring, &m, &inv);
        assert_eq!(prod, Matrix::identity(&ring, n), "case {case}");
    }
}

/// Property: a warm decode-plan cache is **bit-identical** to a cold decode
/// for random responding subsets in random arrival order. The warm scheme
/// accumulates plans across cases; the cold scheme is rebuilt per case (its
/// cache is empty, so its decode computes the plan from scratch).
#[test]
fn prop_cached_ep_decode_bit_identical_to_cold() {
    let mut seeder = Rng64::seeded(8000);
    let ring = Extension::new(Zq::z2e(64), 3);
    let warm = EpCode::new(ring.clone(), 8, 2, 1, 2).unwrap();
    let mut rng = seeder.fork();
    let a = Matrix::random(&ring, 4, 2, &mut rng);
    let b = Matrix::random(&ring, 2, 4, &mut rng);
    let expected = PlaneMatrix::from_aos(&ring, &Matrix::matmul(&ring, &a, &b));
    let shares = warm.encode(&a, &b).unwrap();
    let all: Vec<_> = shares
        .iter()
        .enumerate()
        .map(|(i, s)| (i, warm.worker_compute(s).unwrap()))
        .collect();
    let mut last_subset = Vec::new();
    for case in 0..CASES {
        let mut rng = seeder.fork();
        let subset = rng.choose_k(8, 4); // already in random (arrival) order
        let responses: Vec<_> = subset.iter().map(|&i| all[i].clone()).collect();
        let cold = EpCode::new(ring.clone(), 8, 2, 1, 2).unwrap();
        let c_cold = cold.decode_planes(&responses, 4, 4).unwrap();
        let c_warm = warm.decode_planes(&responses, 4, 4).unwrap();
        assert_eq!(c_cold, c_warm, "case {case}: warm and cold decodes diverged");
        assert_eq!(c_warm, expected, "case {case}: wrong product");
        assert_eq!(cold.plan_cache_stats(), (0, 1), "cold decode computes one plan");
        last_subset = subset;
    }
    // Replaying any already-seen subset must be a guaranteed hit (the cache
    // capacity exceeds the distinct subsets of this run) with the same bits.
    let (hits_before, _) = warm.plan_cache_stats();
    let responses: Vec<_> = last_subset.iter().map(|&i| all[i].clone()).collect();
    assert_eq!(warm.decode_planes(&responses, 4, 4).unwrap(), expected);
    let (hits_after, misses) = warm.plan_cache_stats();
    assert!(hits_after > hits_before, "replayed subset must hit");
    assert_eq!(hits_after + misses, CASES as u64 + 1);
}

/// Property (PR 7 satellite): `Ring::slice_mat_mul_acc`'s hoisted
/// zero-probe (probe each a-panel row once, branch-free dense sweep when
/// zero-free) is bit-identical to the original loop that branched on
/// `is_zero(a_ik)` per element — here reproduced verbatim as the oracle —
/// across ring towers and every forced kernel backend. `a` carries ~25 %
/// zeros so both the sparse and the dense side of the probe run (uniform
/// random elements of a 64-bit ring are never zero in practice).
#[test]
fn prop_hoisted_zero_probe_matmul_bit_identical() {
    /// The pre-hoist loop, verbatim: per-element zero branch inside the
    /// k-panel sweep.
    fn old_loop<B: Ring>(
        base: &B,
        c: &mut [B::Elem],
        a: &[B::Elem],
        b: &[B::Elem],
        dims: [usize; 3],
    ) {
        let [ar, ac, bc] = dims;
        const KB: usize = 64;
        let mut k0 = 0;
        while k0 < ac {
            let kend = (k0 + KB).min(ac);
            for i in 0..ar {
                let crow = &mut c[i * bc..(i + 1) * bc];
                for k in k0..kend {
                    let aik = &a[i * ac + k];
                    if base.is_zero(aik) {
                        continue;
                    }
                    let brow = &b[k * bc..(k + 1) * bc];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        base.mul_add_assign(cj, aik, bj);
                    }
                }
            }
            k0 = kend;
        }
    }

    fn check<B: Ring>(base: &B, seed: u64) {
        let mut seeder = Rng64::seeded(seed);
        for case in 0..8 {
            let mut rng = seeder.fork();
            let (ar, ac, bc) =
                (1 + rng.below_usize(9), 1 + rng.below_usize(70), 1 + rng.below_usize(40));
            let a: Vec<B::Elem> = (0..ar * ac)
                .map(|_| {
                    if rng.below(4) == 0 {
                        base.zero()
                    } else {
                        base.random(&mut rng)
                    }
                })
                .collect();
            let b: Vec<B::Elem> = (0..ac * bc).map(|_| base.random(&mut rng)).collect();
            let c0: Vec<B::Elem> = (0..ar * bc).map(|_| base.random(&mut rng)).collect();
            let mut expect = c0.clone();
            old_loop(base, &mut expect, &a, &b, [ar, ac, bc]);
            for bk in available_backends() {
                let mut got = c0.clone();
                with_backend(bk, || slice_matmul_acc(base, &mut got, &a, &b, ar, ac, bc));
                assert_eq!(
                    got,
                    expect,
                    "{} case {case} backend {} {ar}x{ac}x{bc}",
                    base.name(),
                    bk.name()
                );
            }
        }
    }
    check(&Zq::z2e(64), 13000);
    check(&Zq::z2e(1), 13001);
    check(&Zq::new(3, 5), 13002);
    check(&Zq::new(2147483647, 2), 13003);
    check(&GaloisRing::new(2, 16, 2), 13004);
    check(&Extension::new(Zq::z2e(64), 3), 13005);
}

/// Property: same warm-vs-cold bit-identity for the CSA batch decoder's
/// cached Cauchy–Vandermonde inverse.
#[test]
fn prop_cached_csa_decode_bit_identical_to_cold() {
    let mut seeder = Rng64::seeded(9000);
    let ring = Extension::new(Zq::z2e(64), 4);
    let n_batch = 2; // R = 3 of N = 6
    let warm = CsaCode::new(ring.clone(), 6, n_batch).unwrap();
    let mut rng = seeder.fork();
    let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&ring, 3, 2, &mut rng)).collect();
    let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&ring, 2, 3, &mut rng)).collect();
    let shares = warm.encode_batch(&a, &b).unwrap();
    let all: Vec<_> = shares
        .iter()
        .enumerate()
        .map(|(i, s)| (i, warm.worker_compute(s).unwrap()))
        .collect();
    for case in 0..CASES {
        let mut rng = seeder.fork();
        let subset = rng.choose_k(6, 3);
        let responses: Vec<_> = subset.iter().map(|&i| all[i].clone()).collect();
        let cold = CsaCode::new(ring.clone(), 6, n_batch).unwrap();
        let c_cold = cold.decode_batch(&responses).unwrap();
        let c_warm = warm.decode_batch(&responses).unwrap();
        assert_eq!(c_cold, c_warm, "case {case}: warm and cold decodes diverged");
        for l in 0..n_batch {
            assert_eq!(c_warm[l], Matrix::matmul(&ring, &a[l], &b[l]), "case {case} slot {l}");
        }
    }
    // C(6,3) = 20 < CASES draws: the warm cache must have seen repeats.
    let (hits, misses) = warm.plan_cache_stats();
    assert_eq!(hits + misses, CASES as u64);
    assert!(misses <= 20, "at most one miss per distinct subset");
    assert!(hits >= CASES as u64 - 20, "repeats beyond 20 subsets must hit");
}
