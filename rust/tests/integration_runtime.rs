//! Integration: the python-AOT → rust-PJRT path.
//!
//! Requires `make artifacts` (needs a JAX-capable Python; the tests
//! self-skip when the artifacts or the `pjrt` feature are absent).
//! Validates the cross-language contracts:
//! 1. the deterministic modulus search agrees between
//!    `ring::irreducible::find_irreducible` and
//!    `python/compile/kernels/gr_matmul.py::find_irreducible_gf2`;
//! 2. the AOT-compiled GR worker task is bit-identical to the rust-native
//!    extension-ring matmul;
//! 3. a full coded job decodes correctly with the XLA worker backend.

use gr_cdmm::codes::ep::PlainEp;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::coordinator::{run_single, Coordinator, StragglerModel};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::{ext_matrix_to_planes, planes_to_ext_matrix, XlaShareCompute};
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("GR_CDMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts in {dir} (run `make artifacts`)");
        None
    }
}

/// Open the runtime or skip. Without the `pjrt` feature `XlaRuntime::open`
/// always errors by design, so the artifact tests skip; with the feature, a
/// failure to open existing artifacts is a real regression and fails loudly.
fn open_runtime_or_skip(dir: &str) -> Option<XlaRuntime> {
    match XlaRuntime::open(dir) {
        Ok(rt) => Some(rt),
        #[cfg(not(feature = "pjrt"))]
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
        #[cfg(feature = "pjrt")]
        Err(e) => panic!("artifacts present but the PJRT runtime failed to open: {e}"),
    }
}

/// Contract 1: the canonical GF(2) moduli (these exact constants are also
/// asserted in python/tests/test_gr.py).
#[test]
fn canonical_moduli_cross_language_contract() {
    assert_eq!(Extension::new(Zq::z2e(64), 2).modulus(), &[1, 1, 1]);
    assert_eq!(Extension::new(Zq::z2e(64), 3).modulus(), &[1, 1, 0, 1]);
    assert_eq!(Extension::new(Zq::z2e(64), 4).modulus(), &[1, 1, 0, 0, 1]);
    assert_eq!(Extension::new(Zq::z2e(64), 5).modulus(), &[1, 0, 1, 0, 0, 1]);
}

/// Contract 2a: plain u64 matmul artifact vs rust-native matmul.
#[test]
fn u64_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(runtime) = open_runtime_or_skip(&dir) else { return };
    let spec = runtime.find_spec(1, 128, 128, 128).expect("u64 artifact");
    let artifact = runtime.load(&spec.name.clone()).unwrap();

    let zq = Zq::z2e(64);
    let mut rng = Rng64::seeded(201);
    let a = Matrix::random(&zq, 128, 128, &mut rng);
    let b = Matrix::random(&zq, 128, 128, &mut rng);
    let out = artifact
        .run_u64(&[
            (a.data.clone(), vec![128, 128]),
            (b.data.clone(), vec![128, 128]),
        ])
        .unwrap();
    let expected = Matrix::matmul(&zq, &a, &b);
    assert_eq!(out, expected.data, "XLA artifact must be bit-identical");
}

/// Contract 2b: GR(2^64, 3) worker artifact vs rust-native extension matmul.
#[test]
fn gr_m3_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(runtime) = open_runtime_or_skip(&dir) else { return };
    let Some(spec) = runtime.find_spec(3, 128, 256, 128) else {
        eprintln!("SKIP: m=3 128x256x128 artifact missing");
        return;
    };
    let ext = Extension::new(Zq::z2e(64), 3);
    assert_eq!(spec.modulus, ext.modulus(), "modulus contract");
    let artifact = runtime.load(&spec.name.clone()).unwrap();

    let mut rng = Rng64::seeded(202);
    let a = Matrix::random(&ext, 128, 256, &mut rng);
    let b = Matrix::random(&ext, 256, 128, &mut rng);
    let out = artifact
        .run_u64(&[
            (ext_matrix_to_planes(3, &a), vec![3, 128, 256]),
            (ext_matrix_to_planes(3, &b), vec![3, 256, 128]),
        ])
        .unwrap();
    let got = planes_to_ext_matrix(3, 128, 128, &out);
    let expected = Matrix::matmul(&ext, &a, &b);
    assert_eq!(got, expected, "GR matmul via XLA must match rust-native");
}

/// Contract 3: full coded job (plain EP over GR(2^64,3), N=8, u=v=2, w=1,
/// 256×256 inputs ⇒ shares 128×256 · 256×128) with XLA worker backend.
#[test]
fn coded_job_with_xla_workers() {
    let Some(dir) = artifacts_dir() else { return };
    let base = Zq::z2e(64);
    let scheme = Arc::new(PlainEp::with_m(base.clone(), 3, 8, 2, 1, 2).unwrap());
    let ext = scheme.share_ring().clone();
    let backend = match XlaShareCompute::for_shapes(&dir, ext, 128, 256, 128) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 203);
    let mut rng = Rng64::seeded(204);
    let a = Matrix::random(&base, 256, 256, &mut rng);
    let b = Matrix::random(&base, 256, 256, &mut rng);
    let (c, metrics) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
    assert_eq!(c, Matrix::matmul(&base, &a, &b));
    assert_eq!(metrics.used_workers.len(), 4);
    coord.shutdown();
}
