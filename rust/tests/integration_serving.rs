//! Serving-mode integration: multiple coded jobs in flight on one pool,
//! under every straggler model — correctness of routing, per-job byte
//! accounting against the schemes' analytic volumes, and attribution of
//! late responses to the job that owns them.
//!
//! Jobs deliberately use **distinct input sizes**, so every job's share and
//! response payloads have distinct byte lengths: if the router ever credited
//! a response to the wrong job, the per-job counters could not all match
//! their analytic `upload_bytes`/`download_bytes`.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::codes::DynScheme;
use gr_cdmm::coordinator::transport::ByteCounters;
use gr_cdmm::coordinator::{
    run_verified_erased, ChannelTransport, Coordinator, CorruptionModel, JobHandle,
    NativeCompute, ShareCompute, StragglerModel, VerifyOptions,
};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One submitted job the test tracks to completion.
struct InFlight {
    size: usize,
    expected: Matrix<u64>,
    counters: ByteCounters,
    handle: JobHandle,
}

/// Submit one job per size, all overlapping, on a fresh ep-rmfe-1 pool.
fn submit_stream(
    scheme: &Arc<dyn DynScheme>,
    coord: &mut Coordinator,
    sizes: &[usize],
    seed: u64,
) -> Vec<InFlight> {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    sizes
        .iter()
        .map(|&size| {
            let a = Matrix::random(&base, size, size, &mut rng);
            let b = Matrix::random(&base, size, size, &mut rng);
            let expected = Matrix::matmul(&base, &a, &b);
            let payloads = scheme
                .encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)])
                .unwrap();
            let handle = coord.submit(payloads, scheme.recovery_threshold()).unwrap();
            let counters = handle.counters().clone();
            InFlight { size, expected, counters, handle }
        })
        .collect()
}

/// Wait for a job, decode it, and return the contributing worker ids.
fn collect_and_check(scheme: &Arc<dyn DynScheme>, job: InFlight) -> (Vec<usize>, ByteCounters) {
    let base = Zq::z2e(64);
    let InFlight { size, expected, counters, handle } = job;
    let (collected, _) = handle.wait().unwrap();
    let workers: Vec<usize> = collected.iter().map(|c| c.worker_id).collect();
    let responses: Vec<(usize, &[u8])> =
        collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
    let out = scheme.decode_bytes(&responses).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(
        Matrix::from_bytes(&base, &out[0]).unwrap(),
        expected,
        "job of size {size} decoded wrongly"
    );
    // Per-job wire accounting matches the scheme's analytic model for THIS
    // job's size — impossible if any byte was credited across jobs.
    assert_eq!(
        counters.upload_total() as usize,
        scheme.upload_bytes(size, size, size),
        "upload accounting for size {size}"
    );
    assert_eq!(
        counters.download_used_total() as usize,
        scheme.download_bytes(size, size, size),
        "download accounting for size {size}"
    );
    (workers, counters)
}

#[test]
fn overlapping_jobs_decode_correctly_under_every_straggler_model() {
    let models: Vec<StragglerModel> = vec![
        StragglerModel::None,
        StragglerModel::fixed_slow([6, 7], Duration::from_millis(30)),
        StragglerModel::Exponential { mean: Duration::from_millis(5) },
        StragglerModel::fail_stop([0, 5]),
    ];
    let cfg = SchemeConfig::for_workers(8).unwrap();
    for (k, straggler) in models.into_iter().enumerate() {
        let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, straggler.clone(), 500 + k as u64);
        // five jobs in flight at once, distinct sizes
        let jobs = submit_stream(&scheme, &mut coord, &[8, 16, 24, 32, 40], 600 + k as u64);
        // collect in REVERSE submission order: completion must not depend
        // on collection order
        for job in jobs.into_iter().rev() {
            let (workers, _) = collect_and_check(&scheme, job);
            if let StragglerModel::FailStop { failed } = &straggler {
                for w in &workers {
                    assert!(!failed.contains(w), "failed worker {w} cannot respond");
                }
            }
        }
        coord.shutdown();
    }
}

#[test]
fn late_responses_attributed_to_their_own_job() {
    // Two slow workers answer ~50ms after every job's threshold is met;
    // their bytes must land in the right job's counters as discarded.
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let straggler = StragglerModel::fixed_slow([6, 7], Duration::from_millis(50));
    let mut coord = Coordinator::new(8, backend, straggler, 510);
    let sizes = [8usize, 16, 24, 32];
    let jobs = submit_stream(&scheme, &mut coord, &sizes, 610);
    let per_job: Vec<(usize, ByteCounters)> = jobs
        .into_iter()
        .map(|job| {
            let size = job.size;
            let (_, counters) = collect_and_check(&scheme, job);
            (size, counters)
        })
        .collect();
    // Eventually all 8 workers respond to every job: arrived = 2× the used
    // volume (R = 4 used, 4 more discarded), attributed per job even though
    // the handles are long gone.
    let deadline = Instant::now() + Duration::from_secs(10);
    for (size, counters) in &per_job {
        let used = scheme.download_bytes(*size, *size, *size) as u64;
        while counters.download_arrived_total() < 2 * used {
            assert!(
                Instant::now() < deadline,
                "size-{size} job never saw its late responses attributed"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(counters.download_arrived_total(), 2 * used);
        assert_eq!(counters.download_used_total(), used);
        assert_eq!(counters.download_discarded_total(), used);
    }
    coord.shutdown();
}

#[test]
fn warm_plan_cache_serving_is_bit_identical_and_hits() {
    // Pin the responding subset (exactly R survivors) and serve repeatedly:
    // every decode after the first must hit the plan cache and produce the
    // identical output for identical inputs.
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let straggler = StragglerModel::fail_stop([1, 3, 5, 7]);
    let mut coord = Coordinator::new(8, backend, straggler, 520);
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(620);
    let a = Matrix::random(&base, 16, 16, &mut rng);
    let b = Matrix::random(&base, 16, 16, &mut rng);
    let payload_a = a.to_bytes(&base);
    let payload_b = b.to_bytes(&base);
    let mut outputs = Vec::new();
    for _ in 0..3 {
        let payloads = scheme.encode_bytes(&[payload_a.clone()], &[payload_b.clone()]).unwrap();
        let handle = coord.submit(payloads, scheme.recovery_threshold()).unwrap();
        let (collected, _) = handle.wait().unwrap();
        let responses: Vec<(usize, &[u8])> =
            collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
        outputs.push(scheme.decode_bytes(&responses).unwrap());
    }
    let (hits, misses) = scheme.plan_cache_stats();
    assert_eq!((hits, misses), (2, 1), "subset {{0,2,4,6}} recurs every job");
    assert_eq!(outputs[0], outputs[1], "warm decode must be bit-identical to cold");
    assert_eq!(outputs[1], outputs[2]);
    assert_eq!(
        Matrix::from_bytes(&base, &outputs[0][0]).unwrap(),
        Matrix::matmul(&base, &a, &b)
    );
    coord.shutdown();
}

#[test]
fn byte_ledger_balances_with_rejected_corrupt_responses() {
    // A garbage-payload worker produces corrupt responses the verified
    // decode rejects; their bytes land in the dedicated `rejected` bucket
    // and the download ledger still closes exactly:
    // arrived == used + discarded + rejected.
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep", &cfg).unwrap();
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let transport = ChannelTransport::spawn_faulty(
        8,
        backend,
        StragglerModel::None,
        CorruptionModel::garbage_payload([3]),
        515,
    );
    let mut coord = Coordinator::with_transport(Box::new(transport));
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(615);
    let a = Matrix::random(&base, 16, 16, &mut rng);
    let b = Matrix::random(&base, 16, 16, &mut rng);
    let expected = Matrix::matmul(&base, &a, &b);
    let opts = VerifyOptions::default();
    for _ in 0..2 {
        let (out, metrics) = run_verified_erased(
            &base,
            scheme.as_ref(),
            &mut coord,
            std::slice::from_ref(&a),
            std::slice::from_ref(&b),
            &opts,
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], expected, "the product must match the clean reference");
        assert!(metrics.corrupt_responses_detected >= 1, "{metrics:?}");
    }
    let counters = coord.counters().clone();
    assert!(counters.download_rejected_total() > 0, "rejected bytes must be bucketed");
    assert_eq!(
        counters.download_arrived_total(),
        counters.download_used_total()
            + counters.download_discarded_total()
            + counters.download_rejected_total(),
        "download byte ledger must balance"
    );
    coord.shutdown();
}

#[test]
fn try_wait_multiplexes_many_jobs() {
    // A polling serving loop over 6 jobs with exponential stragglers:
    // completion order is whatever it is; every job must finish correctly.
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let straggler = StragglerModel::Exponential { mean: Duration::from_millis(8) };
    let mut coord = Coordinator::new(8, backend, straggler, 530);
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(630);
    let mut pending = Vec::new();
    for _ in 0..6 {
        let a = Matrix::random(&base, 16, 16, &mut rng);
        let b = Matrix::random(&base, 16, 16, &mut rng);
        let expected = Matrix::matmul(&base, &a, &b);
        let payloads = scheme
            .encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)])
            .unwrap();
        let handle = coord.submit(payloads, scheme.recovery_threshold()).unwrap();
        pending.push((handle, expected));
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut done = 0usize;
    while !pending.is_empty() {
        assert!(Instant::now() < deadline, "polling loop stalled");
        let mut still_pending = Vec::new();
        for (mut handle, expected) in pending {
            match handle.try_wait().unwrap() {
                Some((collected, _)) => {
                    let responses: Vec<(usize, &[u8])> = collected
                        .iter()
                        .map(|c| (c.worker_id, c.payload.as_slice()))
                        .collect();
                    let out = scheme.decode_bytes(&responses).unwrap();
                    assert_eq!(Matrix::from_bytes(&base, &out[0]).unwrap(), expected);
                    done += 1;
                }
                None => still_pending.push((handle, expected)),
            }
        }
        pending = still_pending;
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(done, 6);
    coord.shutdown();
}
