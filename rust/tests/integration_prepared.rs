//! Encode-once (prepared-operand) integration: staging a fixed `A`'s share
//! halves and streaming B-only jobs must decode **bit-identically** to the
//! joint-encode path — on the in-process channel transport and on real TCP
//! daemons, under every straggler model — while the per-job upload drops to
//! the B-halves alone and the staged volume equals the A-halves, byte for
//! byte and identically across transports. Worker flaps mid-stream are
//! re-staged transparently; evicted or released operands fail cleanly.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::codes::DynScheme;
use gr_cdmm::coordinator::runner::{
    make_coordinator, prepare_erased, run_erased, run_prepared_erased,
};
use gr_cdmm::coordinator::{
    Coordinator, NativeCompute, ShareCompute, StragglerModel, WorkerDaemon,
};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::Duration;

const N: usize = 8;
const SIZE: usize = 16;
const JOBS: usize = 3;

fn scheme8() -> Arc<dyn DynScheme> {
    registry::build("ep-rmfe-1", &SchemeConfig::for_workers(N).unwrap()).unwrap()
}

/// One fixed A and a stream of Bs — the fixed-weight serving shape.
fn inputs(seed: u64) -> (Matrix<u64>, Vec<Matrix<u64>>) {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let a = Matrix::random(&base, SIZE, SIZE, &mut rng);
    let bs = (0..JOBS).map(|_| Matrix::random(&base, SIZE, SIZE, &mut rng)).collect();
    (a, bs)
}

#[derive(Clone, Copy)]
enum Kind {
    Channel,
    Tcp,
}

/// Fresh pool of `N` workers for one pass: in-process channels, or one
/// loopback daemon per worker (same straggler model + seed, so the draws
/// match the channel pool exactly).
fn pool(
    kind: Kind,
    scheme: &Arc<dyn DynScheme>,
    straggler: StragglerModel,
    seed: u64,
    conns: usize,
) -> (Coordinator, Vec<WorkerDaemon>) {
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(scheme)));
    match kind {
        Kind::Channel => {
            (make_coordinator(N, backend, straggler, seed, None).unwrap(), Vec::new())
        }
        Kind::Tcp => {
            let daemons: Vec<WorkerDaemon> = (0..N)
                .map(|_| {
                    WorkerDaemon::spawn_local(
                        Arc::clone(&backend),
                        straggler.clone(),
                        seed,
                        conns,
                    )
                    .unwrap()
                })
                .collect();
            let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
            (Coordinator::connect_tcp(&addrs).unwrap(), daemons)
        }
    }
}

fn shutdown(mut coord: Coordinator, daemons: Vec<WorkerDaemon>) {
    coord.shutdown();
    for d in daemons {
        d.join().unwrap();
    }
}

/// The tentpole proof, swept across both transports and all four straggler
/// models: prepared decodes bit-identical to the joint-encode reference,
/// per-job upload exactly the analytic B-side, staged volume exactly the
/// analytic A-side, one A-encode for the whole stream — and the send-side
/// byte accounting identical between channel and TCP pools.
#[test]
fn prepared_matches_joint_encode_on_both_transports_under_all_stragglers() {
    let base = Zq::z2e(64);
    let models: [(&str, StragglerModel); 4] = [
        ("none", StragglerModel::None),
        ("slow", StragglerModel::fixed_slow([0, 1], Duration::from_millis(5))),
        ("exp", StragglerModel::Exponential { mean: Duration::from_millis(2) }),
        ("fail", StragglerModel::fail_stop([N - 1])),
    ];
    for (name, model) in &models {
        // Per-model accounting, compared across the two transports.
        let mut per_transport: Vec<(u64, u64)> = Vec::new();
        for kind in [Kind::Channel, Kind::Tcp] {
            let (a, bs) = inputs(0x9e37 ^ name.len() as u64);

            // Joint-encode reference stream on a fresh pool.
            let ref_scheme = scheme8();
            let (mut coord, daemons) = pool(kind, &ref_scheme, model.clone(), 11, 1);
            let mut want = Vec::new();
            for b in &bs {
                let (out, _) = run_erased(
                    &base,
                    ref_scheme.as_ref(),
                    &mut coord,
                    std::slice::from_ref(&a),
                    std::slice::from_ref(b),
                )
                .unwrap();
                want.push(out);
            }
            shutdown(coord, daemons);

            // Prepared stream: fresh scheme (its A-encode counter starts at
            // zero) and a fresh pool with the same seed (same draws).
            let scheme = scheme8();
            let (mut coord, daemons) = pool(kind, &scheme, model.clone(), 11, 1);
            let id =
                prepare_erased(&base, scheme.as_ref(), &mut coord, std::slice::from_ref(&a))
                    .unwrap();
            let (a_side, b_side) = scheme
                .split_upload_bytes(SIZE, SIZE, SIZE)
                .expect("ep-rmfe-1 has independent operand encodes");
            assert_eq!(
                coord.counters().staged_upload_total(),
                a_side as u64,
                "staging uploads exactly the A-halves ({name})"
            );
            for (b, want) in bs.iter().zip(&want) {
                let (out, m) = run_prepared_erased(
                    &base,
                    scheme.as_ref(),
                    &mut coord,
                    id,
                    std::slice::from_ref(b),
                )
                .unwrap();
                assert_eq!(&out, want, "prepared decode must be bit-identical ({name})");
                assert_eq!(
                    m.upload_bytes, b_side as u64,
                    "a prepared job ships only its B-halves ({name})"
                );
                assert_eq!(m.staged_upload_bytes, 0, "no re-staging in steady state");
                assert_eq!((m.prepared_hits, m.prepared_misses), (1, 0));
            }
            assert_eq!(
                scheme.left_encodes(),
                1,
                "exactly one A-side encode for the whole stream ({name})"
            );
            per_transport
                .push((coord.counters().staged_upload_total(), coord.counters().upload_total()));
            shutdown(coord, daemons);
        }
        assert_eq!(
            per_transport[0], per_transport[1],
            "staged/per-job upload accounting must be transport-independent ({name})"
        );
    }
}

/// A TCP worker link flaps mid-stream. While it is down, its shard of a
/// prepared job fail-stops byte-free and the decode completes from the
/// other `R`-of-`N`; on reconnect the master re-stages exactly that
/// worker's A-half (under the same transport lock, so no prepared job can
/// race ahead of its operand), and the worker serves again.
#[test]
fn tcp_worker_flap_is_restaged_and_prepared_decodes_stay_correct() {
    let base = Zq::z2e(64);
    let scheme = scheme8();
    // Two connections per daemon: the original link plus the re-dial.
    let (mut coord, daemons) = pool(Kind::Tcp, &scheme, StragglerModel::None, 23, 2);
    let (a, bs) = inputs(0x7177);
    let want: Vec<Matrix<u64>> = bs.iter().map(|b| Matrix::matmul(&base, &a, b)).collect();

    let id =
        prepare_erased(&base, scheme.as_ref(), &mut coord, std::slice::from_ref(&a)).unwrap();
    let staged_once = coord.counters().staged_upload_total();
    assert_eq!(staged_once % N as u64, 0, "equal-size halves across the pool");

    let (out, _) = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id,
        std::slice::from_ref(&bs[0]),
    )
    .unwrap();
    assert_eq!(out, vec![want[0].clone()]);

    // Link down: the daemon's staged state dies with the connection.
    coord.disconnect_worker(5).unwrap();
    let (out, m) = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id,
        std::slice::from_ref(&bs[1]),
    )
    .unwrap();
    assert_eq!(out, vec![want[1].clone()], "decode completes from the live R-of-N");
    assert!(!m.used_workers.contains(&5), "the dead worker contributed nothing");

    // Reconnect re-dials and re-stages that worker's half — and only it.
    coord.reconnect_worker(5, None).unwrap();
    assert_eq!(
        coord.counters().staged_upload_total(),
        staged_once + staged_once / N as u64,
        "reconnect re-stages exactly one worker's A-half"
    );
    let (out, _) = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id,
        std::slice::from_ref(&bs[2]),
    )
    .unwrap();
    assert_eq!(out, vec![want[2].clone()]);
    shutdown(coord, daemons);
}

/// Capacity pressure and explicit release: the evicted/released id misses
/// at the store (and is evicted worker-side too), the surviving operand
/// keeps serving bit-identically, and the stats ledger matches exactly.
#[test]
fn evicted_and_released_prepared_operands_fail_cleanly() {
    let base = Zq::z2e(64);
    let scheme = scheme8();
    let (mut coord, daemons) = pool(Kind::Channel, &scheme, StragglerModel::None, 31, 1);
    coord.set_prepared_capacity(1);

    let (a1, bs) = inputs(0x8811);
    let mut rng = Rng64::seeded(0x8822);
    let a2 = Matrix::random(&base, SIZE, SIZE, &mut rng);

    let id1 =
        prepare_erased(&base, scheme.as_ref(), &mut coord, std::slice::from_ref(&a1)).unwrap();
    let id2 =
        prepare_erased(&base, scheme.as_ref(), &mut coord, std::slice::from_ref(&a2)).unwrap();
    assert_ne!(id1, id2);

    // id1 was LRU-evicted by id2's insert: a job naming it is rejected at
    // the master (one counted miss), before any bytes move.
    let err = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id1,
        std::slice::from_ref(&bs[0]),
    )
    .unwrap_err();
    assert!(err.to_string().contains("evicted"), "{err}");

    // id2 still serves, bit-identical to the local reference.
    let (out, m) = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id2,
        std::slice::from_ref(&bs[0]),
    )
    .unwrap();
    assert_eq!(out, vec![Matrix::matmul(&base, &a2, &bs[0])]);
    assert_eq!((m.prepared_hits, m.prepared_misses), (1, 0));

    // Explicit release: the id misses from then on; double-release no-ops.
    assert!(coord.release_prepared(id2).unwrap());
    assert!(!coord.release_prepared(id2).unwrap());
    let err = run_prepared_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        id2,
        std::slice::from_ref(&bs[1]),
    )
    .unwrap_err();
    assert!(err.to_string().contains("evicted"), "{err}");

    // Ledger: one hit (the id2 job), two misses (evicted id1 + released
    // id2), one capacity eviction (release is not an eviction).
    assert_eq!(coord.prepared_stats(), (1, 2, 1));
    shutdown(coord, daemons);
}
