//! Reference-vs-optimized equivalence for the runtime-dispatched base-ring
//! kernels (`ring::arch`).
//!
//! Every backend reachable on this host (always `Reference` and `Generic`;
//! `Native` where the CPU supports it — and even where it doesn't, since
//! `kernels_for(Native)` falls back to the generic table) must produce
//! **bit-identical** results on every `Zq` representation the codebase
//! uses: power-of-two moduli (mask mode) and odd prime powers (Montgomery
//! mode), standalone and as the base of `GaloisRing` / `Extension` towers.
//! Shapes deliberately include lengths that are not multiples of any SIMD
//! lane width, and the `a` operands carry a dense sprinkling of zeros so
//! both sides of the hoisted zero-probe in `Ring::slice_mat_mul_acc` run.
//!
//! The final test drives complete registry schemes end to end through the
//! byte facade and asserts the decode output is backend-invariant.

use gr_cdmm::codes::registry::{self, SchemeConfig, SCHEME_NAMES};
use gr_cdmm::codes::scheme::DynScheme;
use gr_cdmm::ring::arch::{available_backends, kernels_for, with_backend, Backend};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::{slice_matmul_acc_threads, PlaneMatrix};
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::ring::{GaloisRing, Ring};
use gr_cdmm::util::parallel::with_threads;
use gr_cdmm::util::rng::Rng64;

/// The `Zq` representations the equivalence suite sweeps: every mask width
/// class (full-word, partial-word, single-bit) and odd moduli from tiny to
/// near the 2^63 Montgomery ceiling.
fn zq_rings() -> Vec<Zq> {
    vec![
        Zq::z2e(64),
        Zq::z2e(17),
        Zq::z2e(1),
        Zq::new(3, 5),
        Zq::new(7, 3),
        Zq::new(65537, 1),
        Zq::new(2147483647, 2),
    ]
}

/// Backends to force: everything distinct on this host, plus `Native`
/// unconditionally (on hosts without a native path it must degrade to the
/// generic table, not crash).
fn forced_backends() -> Vec<Backend> {
    let mut v = available_backends();
    if !v.contains(&Backend::Native) {
        v.push(Backend::Native);
    }
    v
}

/// Random matrix with ~25 % zero entries — uniform `u64` would essentially
/// never produce a zero in a 64-bit ring, leaving the sparse half of the
/// hoisted zero-probe untested.
fn random_with_zeros(zq: &Zq, rows: usize, cols: usize, rng: &mut Rng64) -> Matrix<u64> {
    let mut m = Matrix::random(zq, rows, cols, rng);
    for x in m.data.iter_mut() {
        if rng.below(4) == 0 {
            *x = 0;
        }
    }
    m
}

#[test]
fn slice_kernels_backend_equivalent_all_rings_and_shapes() {
    let shapes: &[(usize, usize, usize)] =
        &[(1, 1, 1), (1, 7, 5), (3, 4, 13), (5, 5, 8), (7, 64, 33), (16, 16, 16), (2, 130, 31)];
    let mut rng = Rng64::seeded(7001);
    for zq in zq_rings() {
        for &(ar, ac, bc) in shapes {
            let a = random_with_zeros(&zq, ar, ac, &mut rng);
            let b = Matrix::random(&zq, ac, bc, &mut rng);
            let s = zq.random(&mut rng);
            let acc0: Vec<u64> = (0..ar * bc).map(|_| zq.random(&mut rng)).collect();
            let x: Vec<u64> = (0..ar * bc).map(|_| zq.random(&mut rng)).collect();

            let (c_ref, axpy_ref, scale_ref) = with_backend(Backend::Reference, || {
                let c = Matrix::matmul(&zq, &a, &b);
                let mut acc = acc0.clone();
                zq.slice_axpy_assign(&mut acc, &s, &x);
                let mut xs = acc0.clone();
                zq.slice_scale_assign(&mut xs, &s);
                (c, acc, xs)
            });
            // independent oracle for the matmul: plain i-j-k dot products
            // with per-element ring ops, no panels, no skips.
            let mut c_naive = Matrix::zeros(&zq, ar, bc);
            for i in 0..ar {
                for j in 0..bc {
                    let mut acc = 0u64;
                    for k in 0..ac {
                        zq.mul_add_assign(&mut acc, &a.data[i * ac + k], &b.data[k * bc + j]);
                    }
                    c_naive.data[i * bc + j] = acc;
                }
            }
            assert_eq!(c_ref, c_naive, "reference vs naive q={} {ar}x{ac}x{bc}", zq.q());

            for bk in forced_backends() {
                let name = kernels_for(bk).name;
                let (c, axpy, scale) = with_backend(bk, || {
                    let c = Matrix::matmul(&zq, &a, &b);
                    let mut acc = acc0.clone();
                    zq.slice_axpy_assign(&mut acc, &s, &x);
                    let mut xs = acc0.clone();
                    zq.slice_scale_assign(&mut xs, &s);
                    (c, acc, xs)
                });
                assert_eq!(c, c_ref, "matmul {name} q={} {ar}x{ac}x{bc}", zq.q());
                assert_eq!(axpy, axpy_ref, "axpy {name} q={}", zq.q());
                assert_eq!(scale, scale_ref, "scale {name} q={}", zq.q());
            }
        }
    }
}

#[test]
fn tower_plane_ops_backend_invariant() {
    // Extension towers over both representations, incl. the GF(2^d)-style
    // tower over Z_2, exercising matmul + table axpy + in-place scale.
    let towers: Vec<(String, Zq, usize)> = vec![
        ("GR(2^64,4)".into(), Zq::z2e(64), 4),
        ("GF(2^8)".into(), Zq::z2e(1), 8),
        ("GR(3^5,3)".into(), Zq::new(3, 5), 3),
    ];
    let mut rng = Rng64::seeded(7002);
    for (name, base, m) in towers {
        let ext = Extension::new(base.clone(), m);
        let a = Matrix::random(&ext, 9, 7, &mut rng);
        let b = Matrix::random(&ext, 7, 5, &mut rng);
        let s = ext.random(&mut rng);
        let pa = PlaneMatrix::from_aos(&ext, &a);
        let pb = PlaneMatrix::from_aos(&ext, &b);

        let job = || {
            let c = PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1);
            let mut ax = pa.clone();
            ax.axpy(&ext, &s, &pa);
            let mut sc = pa.clone();
            sc.scale_assign(&ext, &s);
            (c, ax, sc)
        };
        let reference = with_backend(Backend::Reference, job);
        for bk in forced_backends() {
            let got = with_backend(bk, job);
            assert_eq!(got, reference, "{name}: {} diverged from reference", kernels_for(bk).name);
        }
    }
}

#[test]
fn galois_ring_matmul_backend_invariant() {
    // GaloisRing's AoS path reaches the dispatched kernels through its Zq
    // coefficient ops only indirectly; still must be backend-invariant.
    let gr = GaloisRing::new(2, 16, 2);
    let mut rng = Rng64::seeded(7003);
    let a = Matrix::random(&gr, 6, 6, &mut rng);
    let b = Matrix::random(&gr, 6, 6, &mut rng);
    let reference = with_backend(Backend::Reference, || Matrix::matmul(&gr, &a, &b));
    for bk in forced_backends() {
        let got = with_backend(bk, || Matrix::matmul(&gr, &a, &b));
        assert_eq!(got, reference, "{}", kernels_for(bk).name);
    }
}

#[test]
fn threaded_matmul_bit_identical_per_backend_and_mixed() {
    // Per backend: the row-panel threaded kernel must equal the sequential
    // one at every thread count. Spawned panel threads read the *process
    // default* backend (the override is thread-local), so the t>1 runs
    // under a forced non-default backend are genuinely mixed-backend — the
    // strongest form of the bit-identity claim.
    let mut rng = Rng64::seeded(7004);
    for zq in [Zq::z2e(64), Zq::new(2147483647, 2)] {
        let (ar, ac, bc) = (37, 65, 29);
        let a = random_with_zeros(&zq, ar, ac, &mut rng);
        let b = Matrix::random(&zq, ac, bc, &mut rng);
        let reference = with_backend(Backend::Reference, || {
            let mut c = vec![0u64; ar * bc];
            slice_matmul_acc_threads(&zq, &mut c, &a.data, &b.data, ar, ac, bc, 1);
            c
        });
        for bk in forced_backends() {
            for t in [1usize, 4] {
                let got = with_backend(bk, || {
                    let mut c = vec![0u64; ar * bc];
                    slice_matmul_acc_threads(&zq, &mut c, &a.data, &b.data, ar, ac, bc, t);
                    c
                });
                assert_eq!(
                    got,
                    reference,
                    "q={} backend={} threads={t}",
                    zq.q(),
                    kernels_for(bk).name
                );
            }
        }
    }
}

/// One full job through the byte facade on the fixed subset `{0..R−1}`.
fn byte_job(
    scheme: &dyn DynScheme,
    a: &[Vec<u8>],
    b: &[Vec<u8>],
) -> (Vec<Vec<u8>>, Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let payloads: Vec<Vec<u8>> =
        scheme.encode_bytes(a, b).unwrap().iter().map(|p| p.to_vec()).collect();
    let rt = scheme.recovery_threshold();
    let responses: Vec<Vec<u8>> =
        (0..rt).map(|i| scheme.compute_bytes(&payloads[i]).unwrap().to_vec()).collect();
    let borrowed: Vec<(usize, &[u8])> =
        responses.iter().enumerate().map(|(i, p)| (i, p.as_slice())).collect();
    let out: Vec<Vec<u8>> =
        scheme.decode_bytes(&borrowed).unwrap().iter().map(|p| p.to_vec()).collect();
    (payloads, responses, out)
}

/// Every registered scheme, end to end: share payloads, worker responses
/// and decoded outputs must not depend on the kernel backend. Run under
/// `with_threads(1)` so the thread-local backend override governs the
/// entire job.
#[test]
fn registry_schemes_backend_invariant_end_to_end() {
    let base = Zq::z2e(64);
    let cfg = SchemeConfig::for_workers(8).unwrap();
    for (name, _) in SCHEME_NAMES {
        let scheme = registry::build(name, &cfg).unwrap();
        let n = scheme.batch_size();
        let mut rng = Rng64::seeded(7005);
        let a: Vec<Vec<u8>> =
            (0..n).map(|_| Matrix::random(&base, 16, 16, &mut rng).to_bytes(&base)).collect();
        let b: Vec<Vec<u8>> =
            (0..n).map(|_| Matrix::random(&base, 16, 16, &mut rng).to_bytes(&base)).collect();
        let reference = with_threads(1, || {
            with_backend(Backend::Reference, || byte_job(scheme.as_ref(), &a, &b))
        });
        for bk in forced_backends() {
            let got =
                with_threads(1, || with_backend(bk, || byte_job(scheme.as_ref(), &a, &b)));
            assert_eq!(
                got,
                reference,
                "{name} under {} diverged from reference backend",
                kernels_for(bk).name
            );
        }
        // mixed-backend + threaded: override on the caller, default on the
        // panel threads — still bit-identical.
        let mixed = with_threads(4, || {
            with_backend(Backend::Generic, || byte_job(scheme.as_ref(), &a, &b))
        });
        assert_eq!(mixed, reference, "{name} threaded mixed-backend run diverged");
    }
}
