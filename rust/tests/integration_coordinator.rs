//! Coordinator integration: coded jobs under adverse cluster conditions,
//! all through the single native backend (`NativeCompute`).

use gr_cdmm::codes::batch_ep_rmfe::BatchEpRmfe;
use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::ep_rmfe_ii::EpRmfeII;
use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::coordinator::runner::{run_batch, run_erased, run_single, NativeCompute};
use gr_cdmm::coordinator::{Coordinator, StragglerModel};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn exponential_stragglers_still_decode() {
    let base = Zq::z2e(64);
    let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let straggler = StragglerModel::Exponential { mean: Duration::from_millis(5) };
    let mut coord = Coordinator::new(8, backend, straggler, 400);
    let mut rng = Rng64::seeded(401);
    for _ in 0..3 {
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, _) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
    }
    coord.shutdown();
}

#[test]
fn max_tolerable_failures() {
    // N − R = 8 − 4 = 4 fail-stop workers: still decodable.
    let base = Zq::z2e(64);
    let scheme = Arc::new(EpRmfeII::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let straggler = StragglerModel::fail_stop([0, 2, 4, 6]);
    let mut coord = Coordinator::new(8, backend, straggler, 402);
    let mut rng = Rng64::seeded(403);
    let a = Matrix::random(&base, 8, 8, &mut rng);
    let b = Matrix::random(&base, 8, 8, &mut rng);
    let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
    assert_eq!(c, Matrix::matmul(&base, &a, &b));
    assert_eq!(m.used_workers.len(), 4);
    for w in &m.used_workers {
        assert!(w % 2 == 1, "only odd workers survived");
    }
    coord.shutdown();
}

#[test]
fn one_failure_too_many_times_out() {
    let base = Zq::z2e(64);
    let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let straggler = StragglerModel::fail_stop([0, 1, 2, 3, 4]); // 5 > N−R
    let mut coord = Coordinator::new(8, backend, straggler, 404);
    coord.timeout = Duration::from_millis(300);
    let mut rng = Rng64::seeded(405);
    let a = Matrix::random(&base, 8, 8, &mut rng);
    let b = Matrix::random(&base, 8, 8, &mut rng);
    assert!(run_single(scheme.as_ref(), &mut coord, &a, &b).is_err());
    coord.shutdown();
}

#[test]
fn sequential_jobs_with_job_id_isolation() {
    // Slow stragglers from job k must not pollute job k+1 (stale job ids
    // are discarded).
    let base = Zq::z2e(64);
    let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let straggler = StragglerModel::fixed_slow([6, 7], Duration::from_millis(60));
    let mut coord = Coordinator::new(8, backend, straggler, 406);
    let mut rng = Rng64::seeded(407);
    for _ in 0..4 {
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, _) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
    }
    coord.shutdown();
}

#[test]
fn batch_job_under_stragglers() {
    let base = Zq::z2e(64);
    let scheme = Arc::new(BatchEpRmfe::new(base.clone(), 16, 2, 2, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let straggler = StragglerModel::fixed_slow([0, 5, 10], Duration::from_millis(80));
    let mut coord = Coordinator::new(16, backend, straggler, 408);
    let mut rng = Rng64::seeded(409);
    let a: Vec<_> = (0..2).map(|_| Matrix::random(&base, 8, 8, &mut rng)).collect();
    let b: Vec<_> = (0..2).map(|_| Matrix::random(&base, 8, 8, &mut rng)).collect();
    let (c, m) = run_batch(scheme.as_ref(), &mut coord, &a, &b).unwrap();
    for k in 0..2 {
        assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
    }
    assert_eq!(m.used_workers.len(), 9);
    coord.shutdown();
}

#[test]
fn download_counters_isolated_per_job() {
    let base = Zq::z2e(64);
    let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 410);
    let mut rng = Rng64::seeded(411);
    let a = Matrix::random(&base, 8, 8, &mut rng);
    let b = Matrix::random(&base, 8, 8, &mut rng);
    let (_, m1) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
    let (_, m2) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
    // every job owns its counters: both jobs report the same volumes, and
    // distinct ids tie the metrics to their jobs.
    assert_eq!(m1.upload_bytes, m2.upload_bytes);
    assert_eq!(m1.download_bytes, m2.download_bytes);
    assert_ne!(m1.job_id, m2.job_id);
    coord.shutdown();
}

#[test]
fn malformed_payloads_fail_cleanly_and_pool_survives() {
    // A truncated/corrupt share must surface as a job failure (every worker
    // reports a compute error, so the collector fails fast with 0 usable
    // responses), NOT a panic unwinding the worker threads — and the same
    // pool must still serve a well-formed job afterwards.
    let base = Zq::z2e(64);
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 412);

    // Garbage payloads: every worker's deserialization errors out.
    let garbage: Vec<Vec<u8>> = (0..8).map(|i| vec![i as u8; 7]).collect();
    let err = coord.submit(garbage, 4).unwrap().wait().unwrap_err();
    assert!(err.to_string().contains("cannot complete"), "{err}");

    // The pool is intact: a real job on the same coordinator succeeds.
    let mut rng = Rng64::seeded(413);
    let a = Matrix::random(&base, 8, 8, &mut rng);
    let b = Matrix::random(&base, 8, 8, &mut rng);
    let (c, _) = run_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        std::slice::from_ref(&a),
        std::slice::from_ref(&b),
    )
    .unwrap();
    assert_eq!(c[0], Matrix::matmul(&base, &a, &b));
    coord.shutdown();
}
