//! Elastic-pool integration: dynamic membership (join, leave, reconnect),
//! health-ranked placement, speculative re-dispatch and the duplicate-
//! response guard — every scenario run over both the in-process
//! `ChannelTransport` and real `TcpTransport` loopback daemons with fixed
//! seeds. Each scenario either completes through straggler tolerance /
//! re-dispatch or fails fast with "cannot complete"; nothing may hang.
//! Per-job byte counters are checked against the analytic volumes.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::codes::DynScheme;
use gr_cdmm::coordinator::master::Collected;
use gr_cdmm::coordinator::{
    ByteCounters, Coordinator, ElasticConfig, NativeCompute, ShareCompute, StragglerModel,
    WorkerDaemon, WorkerHealth,
};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Echo backend for scheme-free membership scenarios.
struct Echo;
impl ShareCompute for Echo {
    fn compute(
        &self,
        _w: usize,
        payload: &[u8],
    ) -> anyhow::Result<gr_cdmm::util::bytepool::PooledBuf> {
        Ok(payload.to_vec().into())
    }
}

/// Which transport a scenario runs over. Every scenario function takes one
/// and is invoked twice — same seeds, same assertions on both sides.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    Channel,
    Tcp,
}

/// One elastic worker pool: a coordinator plus (for TCP) the loopback
/// daemons behind it. The membership verbs forward to the coordinator and
/// handle the transport-specific halves (spawning daemons, endpoints).
struct Pool {
    kind: Kind,
    coord: Coordinator,
    daemons: Vec<WorkerDaemon>,
    backend: Arc<dyn ShareCompute>,
    straggler: StragglerModel,
    seed: u64,
}

impl Pool {
    /// Spawn an `n`-worker pool. `conns` is the per-daemon connection
    /// budget (TCP only): a worker that will be disconnected and re-dialed
    /// needs budget 2 so its daemon's accept loop terminates afterwards.
    fn spawn(
        kind: Kind,
        n: usize,
        backend: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
        conns: &[usize],
    ) -> Pool {
        match kind {
            Kind::Channel => {
                let coord = Coordinator::new(n, Arc::clone(&backend), straggler.clone(), seed);
                Pool { kind, coord, daemons: Vec::new(), backend, straggler, seed }
            }
            Kind::Tcp => {
                assert_eq!(conns.len(), n, "one connection budget per daemon");
                let daemons: Vec<WorkerDaemon> = conns
                    .iter()
                    .map(|&c| {
                        WorkerDaemon::spawn_local(
                            Arc::clone(&backend),
                            straggler.clone(),
                            seed,
                            c,
                        )
                        .unwrap()
                    })
                    .collect();
                let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
                let coord = Coordinator::connect_tcp(&addrs).unwrap();
                Pool { kind, coord, daemons, backend, straggler, seed }
            }
        }
    }

    /// Grow the pool by one worker: the channel transport spawns a thread,
    /// TCP spawns a fresh daemon and dials it.
    fn add_worker(&mut self, conns: usize) -> usize {
        match self.kind {
            Kind::Channel => self.coord.add_worker(None).unwrap(),
            Kind::Tcp => {
                let daemon = WorkerDaemon::spawn_local(
                    Arc::clone(&self.backend),
                    self.straggler.clone(),
                    self.seed,
                    conns,
                )
                .unwrap();
                let addr = daemon.addr();
                self.daemons.push(daemon);
                self.coord.add_worker(Some(&addr)).unwrap()
            }
        }
    }

    /// Shut the coordinator down and join every daemon: proves no scenario
    /// leaks a thread or leaves a daemon's accept loop waiting forever.
    fn finish(self) {
        let Pool { coord, daemons, .. } = self;
        coord.shutdown();
        for daemon in daemons {
            daemon.join().unwrap();
        }
    }
}

/// Distinct per-shard payloads of a fixed length (Echo scenarios).
fn echo_payloads(n: usize, len: usize) -> Vec<Vec<u8>> {
    (0..n).map(|i| vec![i as u8 + 1; len]).collect()
}

/// Sorted shard ids of the collected responses.
fn ids(collected: &[Collected]) -> Vec<usize> {
    let mut v: Vec<usize> = collected.iter().map(|c| c.worker_id).collect();
    v.sort_unstable();
    v
}

/// What one coded job produced: decoded output bytes (bit-comparable
/// across runs), the job's byte counters, which shards were collected, and
/// the dispatch→threshold wall time.
struct CodedRun {
    out: Vec<gr_cdmm::util::bytepool::PooledBuf>,
    counters: ByteCounters,
    used_shards: Vec<usize>,
    wait: Duration,
}

/// Encode one `size×size` product with fixed input seed, submit, collect,
/// decode, and check the result against the local reference product.
fn run_coded_job(
    scheme: &Arc<dyn DynScheme>,
    coord: &mut Coordinator,
    size: usize,
    seed: u64,
) -> CodedRun {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let a = Matrix::random(&base, size, size, &mut rng);
    let b = Matrix::random(&base, size, size, &mut rng);
    let expected = Matrix::matmul(&base, &a, &b);
    let payloads = scheme
        .encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)])
        .unwrap();
    let handle = coord.submit(payloads, scheme.recovery_threshold()).unwrap();
    let counters = handle.counters().clone();
    let (collected, wait) = handle.wait().unwrap();
    let responses: Vec<(usize, &[u8])> =
        collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
    let out = scheme.decode_bytes(&responses).unwrap();
    assert_eq!(
        Matrix::from_bytes(&base, &out[0]).unwrap(),
        expected,
        "decoded product must match the local reference"
    );
    CodedRun { out, counters, used_shards: ids(&collected), wait }
}

/// Speculation + eager fail-fast, but no background re-dialing (keeps
/// dead-worker scenarios deterministic), with a deadline floor high enough
/// that CI scheduling jitter can't make a healthy shard look overdue.
fn speculate_no_reconnect() -> ElasticConfig {
    ElasticConfig {
        speculate: true,
        auto_reconnect: false,
        spec_min_deadline: Duration::from_millis(150),
        ..ElasticConfig::default()
    }
}

// ---------------------------------------------------------------------------
// Scenario 1: slow joiner — the pool starts below the wanted size, a viable
// smaller (N, R) preset runs immediately, and once the late daemons join the
// full preset runs on the same coordinator.
// ---------------------------------------------------------------------------

fn slow_joiner(kind: Kind) {
    let cfg4 = SchemeConfig::for_live_workers(4).unwrap();
    assert_eq!(cfg4.n_workers, 4);
    let scheme4 = registry::build("ep-rmfe-1", &cfg4).unwrap();
    let cfg8 = SchemeConfig::for_live_workers(8).unwrap();
    assert_eq!(cfg8.n_workers, 8);
    let scheme8 = registry::build("ep-rmfe-1", &cfg8).unwrap();

    // The N = 4 and N = 8 presets share the m = 3 tower and partition, so
    // one worker backend serves shares of either scheme.
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(&scheme8)));
    let mut pool = Pool::spawn(kind, 4, backend, StragglerModel::None, 7001, &[1; 4]);
    assert_eq!(pool.coord.live_workers(), 4);

    // Degraded job while only 4 daemons are up: R = N = 4, all must answer.
    let run4 = run_coded_job(&scheme4, &mut pool.coord, 8, 7002);
    assert_eq!(run4.used_shards, vec![0, 1, 2, 3]);
    assert_eq!(run4.counters.upload_total() as usize, scheme4.upload_bytes(8, 8, 8));
    assert_eq!(run4.counters.download_used_total() as usize, scheme4.download_bytes(8, 8, 8));
    assert_eq!(run4.counters.download_arrived_total(), run4.counters.download_used_total());

    // The late daemons join; the full preset now fits.
    for i in 4..8 {
        assert_eq!(pool.add_worker(1), i);
    }
    assert_eq!(pool.coord.n_workers(), 8);
    assert_eq!(pool.coord.live_workers(), 8);

    let run8 = run_coded_job(&scheme8, &mut pool.coord, 8, 7003);
    assert_eq!(run8.used_shards.len(), 4, "R = 4 of N = 8 collected");
    assert_eq!(run8.counters.upload_total() as usize, scheme8.upload_bytes(8, 8, 8));
    assert_eq!(run8.counters.download_used_total() as usize, scheme8.download_bytes(8, 8, 8));

    pool.finish();
    // After the drain all 8 responses have been attributed: uniform
    // response sizes mean arrived is exactly N/R times used.
    assert_eq!(run8.counters.download_arrived_total(), 2 * run8.counters.download_used_total());
}

#[test]
fn slow_joiner_scales_scheme_to_live_workers_channel() {
    slow_joiner(Kind::Channel);
}

#[test]
fn slow_joiner_scales_scheme_to_live_workers_tcp() {
    slow_joiner(Kind::Tcp);
}

#[test]
fn for_live_workers_picks_the_largest_viable_preset() {
    for (live, want) in [(4, 4), (7, 4), (8, 8), (15, 8), (31, 16), (100, 32)] {
        assert_eq!(SchemeConfig::for_live_workers(live).unwrap().n_workers, want);
    }
    let err = SchemeConfig::for_live_workers(3).unwrap_err();
    assert!(err.to_string().contains("needs 4"), "{err}");
}

// ---------------------------------------------------------------------------
// Scenario 2: flapping worker — disconnects between jobs, the pool serves
// degraded with exact byte accounting, then the worker rejoins and serves
// again.
// ---------------------------------------------------------------------------

fn flapping_worker(kind: Kind) {
    let backend: Arc<dyn ShareCompute> = Arc::new(Echo);
    // Worker 2 will be disconnected and re-dialed: its daemon serves 2
    // connections over its lifetime.
    let mut pool = Pool::spawn(kind, 4, backend, StragglerModel::None, 7101, &[1, 1, 2, 1]);

    // Job 1: everyone up, everyone answers.
    let h = pool.coord.submit(echo_payloads(4, 16), 4).unwrap();
    let c1 = h.counters().clone();
    assert_eq!(ids(&h.wait().unwrap().0), vec![0, 1, 2, 3]);
    assert_eq!(c1.upload_total(), 4 * 16);
    assert_eq!(c1.download_used_total(), 4 * 16);

    // Worker 2 drops out. Its shard fail-stops byte-free; the job
    // completes through the straggler slack (need 3 of 4).
    pool.coord.disconnect_worker(2).unwrap();
    assert_eq!(pool.coord.worker_health(2), WorkerHealth::Dead);
    assert_eq!(pool.coord.live_workers(), 3);
    let h = pool.coord.submit(echo_payloads(4, 24), 3).unwrap();
    let c2 = h.counters().clone();
    assert_eq!(ids(&h.wait().unwrap().0), vec![0, 1, 3]);
    assert_eq!(c2.upload_total(), 3 * 24, "the dead link carries zero upload bytes");
    assert_eq!(c2.download_arrived_total(), 3 * 24);
    assert_eq!(c2.download_used_total(), 3 * 24);

    // Worker 2 comes back (same id, same RNG stream) and serves again.
    pool.coord.reconnect_worker(2, None).unwrap();
    assert_eq!(pool.coord.worker_health(2), WorkerHealth::Live);
    assert_eq!(pool.coord.live_workers(), 4);
    let h = pool.coord.submit(echo_payloads(4, 32), 4).unwrap();
    let c3 = h.counters().clone();
    assert_eq!(ids(&h.wait().unwrap().0), vec![0, 1, 2, 3]);
    assert_eq!(c3.upload_total(), 4 * 32);
    assert_eq!(c3.download_used_total(), 4 * 32);

    let agg = pool.coord.counters().clone();
    pool.finish();
    let total = 4 * 16 + 3 * 24 + 4 * 32;
    assert_eq!(agg.upload_total(), total);
    assert_eq!(agg.download_arrived_total(), total);
    assert_eq!(agg.download_used_total(), total);
}

#[test]
fn flapping_worker_leaves_and_rejoins_channel() {
    flapping_worker(Kind::Channel);
}

#[test]
fn flapping_worker_leaves_and_rejoins_tcp() {
    flapping_worker(Kind::Tcp);
}

// ---------------------------------------------------------------------------
// Scenario 3: a worker is lost permanently *mid-job* — the job still
// completes through straggler tolerance; and when too many are lost, the
// job fails fast with "cannot complete" instead of sleeping to a deadline.
// ---------------------------------------------------------------------------

fn lost_mid_job(kind: Kind) {
    let backend: Arc<dyn ShareCompute> = Arc::new(Echo);
    let straggler = StragglerModel::fixed_slow([2], Duration::from_millis(300));
    let mut pool = Pool::spawn(kind, 4, backend, straggler, 7201, &[1; 4]);

    let h = pool.coord.submit(echo_payloads(4, 20), 3).unwrap();
    let counters = h.counters().clone();
    // Let the three fast responses land and worker 2 enter its slow draw,
    // then pull its link mid-job.
    std::thread::sleep(Duration::from_millis(60));
    pool.coord.disconnect_worker(2).unwrap();
    let (got, _) = h.wait().unwrap();
    assert_eq!(ids(&got), vec![0, 1, 3]);
    assert_eq!(counters.upload_total(), 4 * 20, "all four shards were dispatched live");
    assert_eq!(counters.download_used_total(), 3 * 20);

    pool.finish();
    // The sleeping worker's fate differs by transport: the in-process
    // worker wakes and its late bytes still arrive (and are discarded);
    // over TCP the closed socket eats the write, so they never do.
    match kind {
        Kind::Channel => assert_eq!(counters.download_arrived_total(), 4 * 20),
        Kind::Tcp => assert_eq!(counters.download_arrived_total(), 3 * 20),
    }
}

#[test]
fn worker_lost_mid_job_completes_via_tolerance_channel() {
    lost_mid_job(Kind::Channel);
}

#[test]
fn worker_lost_mid_job_completes_via_tolerance_tcp() {
    lost_mid_job(Kind::Tcp);
}

fn hopeless_fails_fast(kind: Kind) {
    let backend: Arc<dyn ShareCompute> = Arc::new(Echo);
    let mut pool = Pool::spawn(kind, 4, backend, StragglerModel::None, 7301, &[1; 4]);
    // A generous deadline proves the failure below is fail-fast detection,
    // not a timeout.
    pool.coord.timeout = Duration::from_secs(60);
    pool.coord.disconnect_worker(1).unwrap();
    pool.coord.disconnect_worker(2).unwrap();

    let t0 = Instant::now();
    let h = pool.coord.submit(echo_payloads(4, 16), 4).unwrap();
    let counters = h.counters().clone();
    let err = h.wait().unwrap_err();
    assert!(err.to_string().contains("cannot complete"), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(10), "must fail fast, not hit the deadline");
    assert_eq!(counters.upload_total(), 2 * 16, "only the live links carry bytes");
    assert_eq!(counters.download_used_total(), 2 * 16);
    pool.finish();
}

#[test]
fn hopeless_job_fails_fast_channel() {
    hopeless_fails_fast(Kind::Channel);
}

#[test]
fn hopeless_job_fails_fast_tcp() {
    hopeless_fails_fast(Kind::Tcp);
}

/// The same two-dead-workers pool, but with speculation on: the shards that
/// fail-stopped on the dead links are re-dispatched to live spares and the
/// job completes with all four shards.
fn dead_shards_respeculate(kind: Kind) {
    let backend: Arc<dyn ShareCompute> = Arc::new(Echo);
    let mut pool = Pool::spawn(kind, 4, backend, StragglerModel::None, 7351, &[1; 4]);
    pool.coord.set_elastic(speculate_no_reconnect());
    pool.coord.disconnect_worker(1).unwrap();
    pool.coord.disconnect_worker(2).unwrap();

    let h = pool.coord.submit(echo_payloads(4, 16), 4).unwrap();
    let counters = h.counters().clone();
    let (got, _) = h.wait().unwrap();
    assert_eq!(ids(&got), vec![0, 1, 2, 3], "every shard answered, two via spares");
    assert_eq!(counters.speculative_total(), 2);
    assert_eq!(counters.upload_total(), 4 * 16, "2 live dispatches + 2 re-dispatches");
    assert_eq!(counters.download_used_total(), 4 * 16);
    pool.finish();
}

#[test]
fn dead_shards_are_respeculated_to_spares_channel() {
    dead_shards_respeculate(Kind::Channel);
}

#[test]
fn dead_shards_are_respeculated_to_spares_tcp() {
    dead_shards_respeculate(Kind::Tcp);
}

// ---------------------------------------------------------------------------
// Scenario 4: skewed heterogeneous pool — half the workers are much slower;
// the job must complete from the fast half well before the slow half's
// delay, and the latency tracker must have learned the fast workers.
// ---------------------------------------------------------------------------

fn skewed_pool(kind: Kind) {
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    let straggler = StragglerModel::fixed_slow([0, 1, 2, 3], Duration::from_millis(400));
    let mut pool = Pool::spawn(kind, 8, backend, straggler, 7401, &[1; 8]);

    let run = run_coded_job(&scheme, &mut pool.coord, 8, 7402);
    assert_eq!(run.used_shards, vec![4, 5, 6, 7], "only the fast half is collected");
    assert!(run.wait < Duration::from_millis(350), "collected in {:?}", run.wait);
    assert_eq!(run.counters.upload_total() as usize, scheme.upload_bytes(8, 8, 8));
    assert_eq!(run.counters.download_used_total() as usize, scheme.download_bytes(8, 8, 8));

    // The fast workers' responses fed the latency estimator (one
    // unambiguous sample each); the slow half hasn't answered yet.
    let snap = pool.coord.pool_snapshot();
    for s in &snap[4..8] {
        assert_eq!(s.samples, 1);
    }

    pool.finish();
    // The drain waits for the slow half: all 8 responses attributed.
    assert_eq!(run.counters.download_arrived_total(), 2 * run.counters.download_used_total());
}

#[test]
fn skewed_pool_collects_the_fast_half_channel() {
    skewed_pool(Kind::Channel);
}

#[test]
fn skewed_pool_collects_the_fast_half_tcp() {
    skewed_pool(Kind::Tcp);
}

// ---------------------------------------------------------------------------
// Scenario 5 (property): speculative re-dispatch is decode-invariant — the
// rescued run decodes to bit-identical output bytes, and the loser of a
// speculative race is dropped before it can double-count or reach a decode.
// ---------------------------------------------------------------------------

fn speculative_rescue_decode_invariant(kind: Kind) {
    // R = N = 4: no straggler slack, so losing worker 3 is fatal without
    // re-dispatch.
    let cfg = SchemeConfig::for_workers(4).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(&scheme)));

    // Baseline: clean 4-worker pool, speculation off.
    let mut clean =
        Pool::spawn(kind, 4, Arc::clone(&backend), StragglerModel::None, 7501, &[1; 4]);
    let base_run = run_coded_job(&scheme, &mut clean.coord, 8, 7502);
    clean.finish();

    // Rescued: 5 workers, shards land on 0..4, worker 3 fail-stops; the
    // monitor re-dispatches shard 3 to a live spare machine.
    let mut pool = Pool::spawn(kind, 5, backend, StragglerModel::fail_stop([3]), 7501, &[1; 5]);
    pool.coord.set_elastic(speculate_no_reconnect());
    let spec_run = run_coded_job(&scheme, &mut pool.coord, 8, 7502);
    assert_eq!(spec_run.counters.speculative_total(), 1);
    assert_eq!(spec_run.used_shards, vec![0, 1, 2, 3]);
    pool.finish();

    assert_eq!(
        spec_run.out, base_run.out,
        "rescued decode must be bit-identical to the no-speculation run"
    );
}

#[test]
fn speculative_rescue_is_decode_invariant_channel() {
    speculative_rescue_decode_invariant(Kind::Channel);
}

#[test]
fn speculative_rescue_is_decode_invariant_tcp() {
    speculative_rescue_decode_invariant(Kind::Tcp);
}

fn speculative_race_duplicate_dropped(kind: Kind) {
    let cfg = SchemeConfig::for_workers(4).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
    // Worker 3 answers eventually — long after its shard's speculative copy
    // (overdue at the 150 ms floor) has already won the race.
    let straggler = StragglerModel::fixed_slow([3], Duration::from_millis(500));
    let mut pool = Pool::spawn(kind, 5, backend, straggler, 7601, &[1; 5]);
    pool.coord.set_elastic(speculate_no_reconnect());

    let run = run_coded_job(&scheme, &mut pool.coord, 8, 7602);
    assert_eq!(run.counters.speculative_total(), 1);
    assert_eq!(run.used_shards, vec![0, 1, 2, 3]);
    // Exactly one success per shard was forwarded: the entry retired once
    // (a double-decrement of `outstanding` would have panicked the router
    // or left the job registered).
    assert_eq!(pool.coord.jobs_in_flight(), 0);

    let agg = pool.coord.counters().clone();
    pool.finish();
    // The losing copy arrives after the job retired: its bytes are counted
    // (and discarded) in the aggregate only, never credited to the job —
    // so the job's accounting is identical to a no-race run.
    let per_resp = (scheme.download_bytes(8, 8, 8) / scheme.recovery_threshold()) as u64;
    assert_eq!(run.counters.download_arrived_total(), run.counters.download_used_total());
    assert_eq!(agg.download_arrived_total(), run.counters.download_used_total() + per_resp);
    assert_eq!(agg.download_discarded_total(), per_resp);
}

#[test]
fn speculative_race_duplicate_never_double_counts_channel() {
    speculative_race_duplicate_dropped(Kind::Channel);
}

#[test]
fn speculative_race_duplicate_never_double_counts_tcp() {
    speculative_race_duplicate_dropped(Kind::Tcp);
}
