//! Memory-discipline integration: steady-state serving must perform **zero
//! per-job large allocations and zero redundant payload copies** — the
//! zero-copy analogue of the decode path's `scalar_table_builds()` probe.
//!
//! The proof is counter-based: after two warm-up passes populate the global
//! byte pool, 20 mixed-shape jobs must show a zero pool-miss delta (100%
//! hit rate), a zero `large_allocs()` delta and a zero `copied_bytes()`
//! delta — on every transport (in-process channel, TCP loopback, shm
//! rings) and at both serial and parallel encode thread counts. A final
//! triple run asserts the per-job byte ledger is identical across all
//! three transports, and a rogue shm peer degrades to fail-stop through
//! the public coordinator API.
//!
//! Every test locks one global mutex: the pool and its counters are
//! process-wide, so concurrent tests would pollute each other's deltas.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::codes::DynScheme;
use gr_cdmm::coordinator::wire::{self, Frame, FrameKind};
use gr_cdmm::coordinator::{
    shm, Coordinator, DaemonConfig, NativeCompute, ShareCompute, StragglerModel, WorkerDaemon,
};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::bytepool::{self, BytePool};
use gr_cdmm::util::parallel::with_threads;
use gr_cdmm::util::rng::Rng64;
use std::io::BufReader;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serializes every test in this binary: the byte pool, its hit/miss
/// counters and the `large_allocs`/`copied_bytes` probes are global.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn pool_guard() -> MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The mixed job shapes: distinct sizes ⇒ distinct payload buckets, so a
/// pool that only survived uniform traffic would be caught here.
const SHAPES: [usize; 3] = [8, 16, 24];

/// Which transport backs the pool under test.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Kind {
    Channel,
    Tcp,
    Shm,
}

/// A live pool plus the daemons (if any) backing it.
struct Pool {
    coord: Coordinator,
    daemons: Vec<WorkerDaemon>,
}

fn make_pool(kind: Kind, scheme: &Arc<dyn DynScheme>, seed: u64) -> Pool {
    let n = 8;
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(scheme)));
    match kind {
        Kind::Channel => Pool {
            coord: Coordinator::new(
                n,
                Arc::new(NativeCompute::new(Arc::clone(scheme))),
                StragglerModel::None,
                seed,
            ),
            daemons: Vec::new(),
        },
        Kind::Tcp => {
            let daemons: Vec<WorkerDaemon> = (0..n)
                .map(|_| {
                    WorkerDaemon::spawn_local(Arc::clone(&backend), StragglerModel::None, seed, 1)
                        .unwrap()
                })
                .collect();
            let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
            Pool { coord: Coordinator::connect_tcp(&addrs).unwrap(), daemons }
        }
        Kind::Shm => {
            let dir = shm::unique_ring_dir("alloc").unwrap();
            let daemons: Vec<WorkerDaemon> = (0..n)
                .map(|_| {
                    WorkerDaemon::spawn_local_cfg(
                        Arc::clone(&backend),
                        DaemonConfig {
                            straggler: StragglerModel::None,
                            seed,
                            shm_dir: Some(dir.clone()),
                            ..DaemonConfig::default()
                        },
                        1,
                    )
                    .unwrap()
                })
                .collect();
            let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
            Pool { coord: Coordinator::connect_shm(&addrs, &dir).unwrap(), daemons }
        }
    }
}

impl Pool {
    fn finish(mut self) {
        self.coord.shutdown();
        for daemon in self.daemons {
            daemon.join().unwrap();
        }
    }
}

/// Run one submit-wait-decode job of the given size and assert the product.
fn one_job(scheme: &Arc<dyn DynScheme>, coord: &mut Coordinator, size: usize, rng: &mut Rng64) {
    let base = Zq::z2e(64);
    let a = Matrix::random(&base, size, size, rng);
    let b = Matrix::random(&base, size, size, rng);
    let expected = Matrix::matmul(&base, &a, &b);
    let payloads = scheme.encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)]).unwrap();
    let handle = coord.submit(payloads, scheme.recovery_threshold()).unwrap();
    let (collected, _) = handle.wait().unwrap();
    let responses: Vec<(usize, &[u8])> =
        collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
    let out = scheme.decode_bytes(&responses).unwrap();
    assert_eq!(Matrix::from_bytes(&base, &out[0]).unwrap(), expected, "size {size}");
}

/// The zero-alloc proof for one transport: two warm-up passes over the
/// mixed shapes, then 20 measured jobs with zero misses, zero large
/// allocations and zero copies.
fn assert_zero_alloc_steady_state(kind: Kind, seed: u64) {
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let mut rng = Rng64::seeded(seed);
    let mut pool = make_pool(kind, &scheme, seed);

    // Warm-up: two passes over every shape populate each payload bucket
    // with enough buffers for the steady state (shares out, responses in).
    for _ in 0..2 {
        for &size in &SHAPES {
            one_job(&scheme, &mut pool.coord, size, &mut rng);
        }
    }
    // Surplus responses of the last warm-up job may still be in flight;
    // give them a moment to land and return their buffers to the pool.
    std::thread::sleep(Duration::from_millis(100));

    let stats_before = BytePool::global().stats();
    let large_before = bytepool::large_allocs();
    let copied_before = bytepool::copied_bytes();
    for job in 0..20 {
        one_job(&scheme, &mut pool.coord, SHAPES[job % SHAPES.len()], &mut rng);
    }
    let stats_after = BytePool::global().stats();
    let miss_delta = stats_after.misses - stats_before.misses;
    let hit_delta = stats_after.hits - stats_before.hits;
    assert_eq!(
        miss_delta, 0,
        "{kind:?}: steady state must lease every buffer from the pool \
         ({hit_delta} hits, {miss_delta} misses)"
    );
    assert!(hit_delta > 0, "{kind:?}: the measured jobs must actually lease buffers");
    assert_eq!(
        bytepool::large_allocs() - large_before,
        0,
        "{kind:?}: zero per-job large allocations in steady state"
    );
    assert_eq!(
        bytepool::copied_bytes() - copied_before,
        0,
        "{kind:?}: zero redundant payload copies in steady state"
    );
    pool.finish();
}

#[test]
fn channel_steady_state_is_zero_alloc() {
    let _g = pool_guard();
    with_threads(1, || assert_zero_alloc_steady_state(Kind::Channel, 7001));
    with_threads(4, || assert_zero_alloc_steady_state(Kind::Channel, 7002));
}

#[test]
fn tcp_loopback_steady_state_is_zero_alloc() {
    let _g = pool_guard();
    with_threads(1, || assert_zero_alloc_steady_state(Kind::Tcp, 7011));
    with_threads(4, || assert_zero_alloc_steady_state(Kind::Tcp, 7012));
}

#[test]
fn shm_steady_state_is_zero_alloc() {
    let _g = pool_guard();
    with_threads(1, || assert_zero_alloc_steady_state(Kind::Shm, 7021));
    with_threads(4, || assert_zero_alloc_steady_state(Kind::Shm, 7022));
}

/// One batch over a transport, returning per-job and aggregate byte
/// ledgers (read after shutdown so every late response is attributed).
fn batch_ledger(kind: Kind, seed: u64) -> (Vec<(u64, u64, u64)>, (u64, u64, u64)) {
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let mut pool = make_pool(kind, &scheme, seed);
    let mut per_job = Vec::new();
    for &size in &SHAPES {
        let a = Matrix::random(&base, size, size, &mut rng);
        let b = Matrix::random(&base, size, size, &mut rng);
        let payloads = scheme.encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)]).unwrap();
        let handle = pool.coord.submit(payloads, scheme.recovery_threshold()).unwrap();
        let counters = handle.counters().clone();
        handle.wait().unwrap();
        per_job.push(counters);
    }
    let aggregate = pool.coord.counters().clone();
    pool.finish(); // drains the workers: every surplus response is routed
    (
        per_job
            .iter()
            .map(|c| (c.upload_total(), c.download_used_total(), c.download_arrived_total()))
            .collect(),
        (
            aggregate.upload_total(),
            aggregate.download_used_total(),
            aggregate.download_arrived_total(),
        ),
    )
}

#[test]
fn byte_ledger_is_identical_across_channel_tcp_and_shm() {
    // The shm data plane moves payloads out-of-line, but the per-job
    // ledger must not know: upload, used and arrived byte totals are
    // payload bytes, identical across all three transports.
    let _g = pool_guard();
    let chan = batch_ledger(Kind::Channel, 512);
    let tcp = batch_ledger(Kind::Tcp, 512);
    let shm = batch_ledger(Kind::Shm, 512);
    assert_eq!(chan, tcp, "channel vs tcp-loopback byte ledgers diverged");
    assert_eq!(tcp, shm, "tcp-loopback vs shm byte ledgers diverged");
}

#[test]
fn rogue_shm_slot_reference_fails_the_job_cleanly() {
    // A rogue peer on the shm control channel answers the job doorbell
    // with a reference to a ring slot that was never written. Through the
    // public coordinator API this must surface as a per-job failure —
    // never a hang, never a panic, never garbage bytes decoded.
    let _g = pool_guard();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let rogue = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let hello = wire::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(hello.kind, FrameKind::Hello);
        wire::write_frame(&mut &stream, &Frame::hello(0)).unwrap();
        let job = wire::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(job.kind, FrameKind::JobRef, "a small payload rides the ring");
        wire::write_frame(
            &mut &stream,
            &Frame::resp_ref(job.job_id, 0, Duration::ZERO, Duration::ZERO, 99, 16),
        )
        .unwrap();
        let _ = wire::read_frame(&mut reader); // hold until the master kills the link
    });
    let dir = shm::unique_ring_dir("rogue-it").unwrap();
    let mut coord = Coordinator::connect_shm(&[addr], &dir).unwrap();
    let err = coord.submit(vec![vec![3u8; 64]], 1).unwrap().wait().unwrap_err();
    assert!(err.to_string().contains("cannot complete"), "{err}");
    coord.shutdown();
    rogue.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
