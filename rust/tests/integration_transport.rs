//! Transport-layer integration: the same coded job batch must behave
//! identically over the in-process `ChannelTransport` and the socket-backed
//! `TcpTransport` (loopback daemons) — identical decoded products and
//! identical upload/download byte accounting under deterministic straggler
//! draws — and every way a TCP peer can misbehave (disconnects mid-job,
//! garbage bytes, truncated frames, oversized declared payloads) must
//! surface as a clean per-job failure, never a panic or a hang.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::codes::DynScheme;
use gr_cdmm::coordinator::wire::{self, Frame, FrameKind};
use gr_cdmm::coordinator::{
    ChannelTransport, Coordinator, CorruptionModel, DaemonConfig, JobHandle, NativeCompute,
    ShareCompute, StragglerModel, WorkerDaemon,
};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Echo backend for scheme-free transport tests.
struct Echo;
impl ShareCompute for Echo {
    fn compute(
        &self,
        _w: usize,
        payload: &[u8],
    ) -> anyhow::Result<gr_cdmm::util::bytepool::PooledBuf> {
        Ok(payload.to_vec().into())
    }
}

/// What one pass over a job batch measured: decoded outputs plus per-job
/// and aggregate byte counters (read after shutdown, when every late
/// response has been routed and attributed).
struct BatchResult {
    decoded: Vec<Vec<Vec<u8>>>,
    per_job: Vec<(u64, u64, u64)>, // (upload, download_used, download_arrived)
    aggregate: (u64, u64, u64),
}

/// Submit `sizes.len()` overlapping jobs (distinct sizes ⇒ distinct byte
/// volumes), collect them all, decode, shut down, and read the counters.
fn run_batch(
    scheme: &Arc<dyn DynScheme>,
    mut coord: Coordinator,
    sizes: &[usize],
    seed: u64,
) -> BatchResult {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let mut handles: Vec<JobHandle> = Vec::new();
    let mut expected = Vec::new();
    for &size in sizes {
        let a = Matrix::random(&base, size, size, &mut rng);
        let b = Matrix::random(&base, size, size, &mut rng);
        expected.push(Matrix::matmul(&base, &a, &b));
        let payloads = scheme
            .encode_bytes(&[a.to_bytes(&base)], &[b.to_bytes(&base)])
            .unwrap();
        handles.push(coord.submit(payloads, scheme.recovery_threshold()).unwrap());
    }
    let mut decoded = Vec::new();
    let mut job_counters = Vec::new();
    for (handle, want) in handles.into_iter().zip(&expected) {
        job_counters.push(handle.counters().clone());
        let (collected, _) = handle.wait().unwrap();
        let responses: Vec<(usize, &[u8])> =
            collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
        let out = scheme.decode_bytes(&responses).unwrap();
        assert_eq!(
            Matrix::from_bytes(&base, &out[0]).unwrap(),
            *want,
            "decoded product must match the local reference"
        );
        decoded.push(out);
    }
    let aggregate = coord.counters().clone();
    coord.shutdown(); // drains every worker: late responses are all routed
    BatchResult {
        decoded,
        per_job: job_counters
            .iter()
            .map(|c| (c.upload_total(), c.download_used_total(), c.download_arrived_total()))
            .collect(),
        aggregate: (
            aggregate.upload_total(),
            aggregate.download_used_total(),
            aggregate.download_arrived_total(),
        ),
    }
}

/// One channel-vs-TCP comparison under a given (deterministic) straggler
/// model: same scheme, same job sizes, same seeds on both sides.
fn assert_tcp_matches_channel(straggler: StragglerModel, seed: u64) {
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let sizes = [8usize, 16, 24];

    let chan_scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let chan_coord = Coordinator::new(
        8,
        Arc::new(NativeCompute::new(Arc::clone(&chan_scheme))),
        straggler.clone(),
        seed,
    );
    assert_eq!(chan_coord.transport_name(), "channel");
    let chan = run_batch(&chan_scheme, chan_coord, &sizes, seed ^ 0xA5);

    let tcp_scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
    let backend: Arc<dyn ShareCompute> =
        Arc::new(NativeCompute::new(Arc::clone(&tcp_scheme)));
    let daemons: Vec<WorkerDaemon> = (0..8)
        .map(|_| {
            WorkerDaemon::spawn_local(Arc::clone(&backend), straggler.clone(), seed, 1).unwrap()
        })
        .collect();
    let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
    let tcp_coord = Coordinator::connect_tcp(&addrs).unwrap();
    assert_eq!(tcp_coord.transport_name(), "tcp");
    let tcp = run_batch(&tcp_scheme, tcp_coord, &sizes, seed ^ 0xA5);
    for daemon in daemons {
        daemon.join().unwrap();
    }

    // Identical products, byte for byte (the inputs are identical, and ring
    // arithmetic is exact on both sides of the wire).
    assert_eq!(chan.decoded, tcp.decoded, "decoded outputs diverged across transports");
    // Identical accounting: upload, used and arrived, per job and overall.
    assert_eq!(chan.per_job, tcp.per_job, "per-job byte counters diverged across transports");
    assert_eq!(chan.aggregate, tcp.aggregate, "aggregate counters diverged across transports");
    // And the analytic model holds for both (spot-check through one side).
    for (&size, &(upload, used, _)) in sizes.iter().zip(&tcp.per_job) {
        assert_eq!(upload as usize, tcp_scheme.upload_bytes(size, size, size));
        assert_eq!(used as usize, tcp_scheme.download_bytes(size, size, size));
    }
}

#[test]
fn tcp_loopback_matches_channel_no_stragglers() {
    assert_tcp_matches_channel(StragglerModel::None, 900);
}

#[test]
fn tcp_loopback_matches_channel_fixed_slow() {
    assert_tcp_matches_channel(
        StragglerModel::fixed_slow([0, 1], Duration::from_millis(15)),
        901,
    );
}

#[test]
fn tcp_loopback_matches_channel_fail_stop() {
    // Fail-stop daemons still read the share (upload counted on both
    // transports) and answer with a byte-free failure report.
    assert_tcp_matches_channel(StragglerModel::fail_stop([2, 5]), 902);
}

/// The per-worker payload of job `job` in the corruption parity runs.
fn parity_payload(job: u8, worker: usize) -> Vec<u8> {
    vec![job * 16 + worker as u8 + 1; 24]
}

/// Run two sequential 4-worker echo jobs under `model` and return every
/// response's bytes, sorted by worker, one Vec per job.
fn corrupt_responses_for(
    model: &CorruptionModel,
    tcp: bool,
    seed: u64,
) -> Vec<Vec<(usize, Vec<u8>)>> {
    let n = 4;
    let backend: Arc<dyn ShareCompute> = Arc::new(Echo);
    let (mut coord, daemons) = if tcp {
        let daemons: Vec<WorkerDaemon> = (0..n)
            .map(|_| {
                WorkerDaemon::spawn_local_cfg(
                    Arc::clone(&backend),
                    DaemonConfig {
                        straggler: StragglerModel::None,
                        corrupt: model.clone(),
                        seed,
                        ..DaemonConfig::default()
                    },
                    1,
                )
                .unwrap()
            })
            .collect();
        let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
        (Coordinator::connect_tcp(&addrs).unwrap(), daemons)
    } else {
        let transport = ChannelTransport::spawn_faulty(
            n,
            Arc::clone(&backend),
            StragglerModel::None,
            model.clone(),
            seed,
        );
        (Coordinator::with_transport(Box::new(transport)), Vec::new())
    };
    let mut jobs = Vec::new();
    for job in 0..2u8 {
        let payloads: Vec<Vec<u8>> = (0..n).map(|w| parity_payload(job, w)).collect();
        let (collected, _) = coord.submit(payloads, n).unwrap().wait().unwrap();
        let mut got: Vec<(usize, Vec<u8>)> =
            collected.into_iter().map(|c| (c.worker_id, c.payload.to_vec())).collect();
        got.sort_by_key(|&(w, _)| w);
        jobs.push(got);
    }
    coord.shutdown();
    for daemon in daemons {
        daemon.join().unwrap();
    }
    jobs
}

#[test]
fn corruption_draws_match_across_transports() {
    // Mirror of the straggler parity tests above for the Byzantine models:
    // same model + same seed must corrupt byte-for-byte identically whether
    // the drawing happens in the channel pool or in a TCP daemon — that is
    // what makes Byzantine fault scenarios reproducible across transports.
    for model in [
        CorruptionModel::bit_flip([1]),
        CorruptionModel::garbage_payload([2]),
        CorruptionModel::stale_replay([1, 3]),
        CorruptionModel::silent_wrong_share([0]),
    ] {
        let chan = corrupt_responses_for(&model, false, 606);
        let tcp = corrupt_responses_for(&model, true, 606);
        assert_eq!(
            chan, tcp,
            "corrupt draws diverged across transports for {}",
            model.label()
        );
        // And the injection actually fired (parity alone would also hold if
        // corruption were silently a no-op everywhere).
        match &model {
            CorruptionModel::StaleReplay { .. } => {
                // First job has nothing to replay (clean); the second job's
                // targeted workers replay their first clean response.
                for &w in &[1usize, 3] {
                    assert_eq!(chan[0][w].1, parity_payload(0, w));
                    assert_eq!(chan[1][w].1, parity_payload(0, w), "worker {w} must replay");
                }
            }
            _ => {
                let target = match &model {
                    CorruptionModel::BitFlip { .. } => 1usize,
                    CorruptionModel::GarbagePayload { .. } => 2,
                    _ => 0,
                };
                assert_ne!(
                    chan[0][target].1,
                    parity_payload(0, target),
                    "{} must corrupt worker {target}'s response",
                    model.label()
                );
            }
        }
        // Untargeted workers echo cleanly on every model.
        for (job, responses) in chan.iter().enumerate() {
            for &(w, ref payload) in responses {
                if !model.targets(w) {
                    assert_eq!(*payload, parity_payload(job as u8, w), "worker {w} is clean");
                }
            }
        }
    }
}

/// A rogue "worker": accepts one connection, optionally reads `read_frames`
/// frames (the master opens every connection with a hello frame, so the
/// first read is that handshake and job frames follow), writes `reply`
/// verbatim, then slams the connection.
fn rogue_listener(read_frames: usize, reply: Vec<u8>) -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        for _ in 0..read_frames {
            if wire::read_frame(&mut reader).ok().flatten().is_none() {
                break;
            }
        }
        let _ = stream.write_all(&reply);
        // dropping both halves closes the connection mid-job
    });
    (addr, handle)
}

/// Build a 2-worker TCP pool: worker 0 is a healthy Echo daemon, worker 1
/// is the given rogue endpoint.
fn echo_plus_rogue(rogue_addr: String) -> (Coordinator, WorkerDaemon) {
    let daemon =
        WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::None, 7, 1).unwrap();
    let addrs = vec![daemon.addr(), rogue_addr];
    (Coordinator::connect_tcp(&addrs).unwrap(), daemon)
}

/// The healthy worker still answers and the rogue one degrades to
/// fail-stop: `need = 1` succeeds, `need = 2` fails fast with "cannot
/// complete" — and a *second* job on the now-dead link fails just as
/// cleanly (the writer side synthesizes the failure report).
fn assert_rogue_degrades_to_fail_stop(rogue_addr: String, rogue: JoinHandle<()>) {
    let (mut coord, daemon) = echo_plus_rogue(rogue_addr);
    coord.timeout = Duration::from_secs(30); // a hang must not masquerade as a straggler

    let payloads = || vec![vec![1u8; 16], vec![2u8; 16]];
    let handle = coord.submit(payloads(), 1).unwrap();
    let (got, _) = handle.wait().unwrap();
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].worker_id, 0, "only the healthy worker can answer");

    let err = coord.submit(payloads(), 2).unwrap().wait().unwrap_err();
    assert!(err.to_string().contains("cannot complete"), "{err}");

    // a later job on the (by now) dead link fails just as cleanly, whether
    // the writer synthesizes the report at dispatch or the reader's drain
    // beats it to the punch
    let err = coord.submit(payloads(), 2).unwrap().wait().unwrap_err();
    assert!(err.to_string().contains("cannot complete"), "{err}");

    coord.shutdown();
    daemon.join().unwrap();
    rogue.join().unwrap();
}

#[test]
fn mid_job_disconnect_is_a_clean_per_job_failure() {
    // reads the hello and one job frame, never replies, closes
    let (addr, rogue) = rogue_listener(2, Vec::new());
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn garbage_frames_are_a_clean_per_job_failure() {
    // replies with 64 bytes of garbage instead of a response frame
    let (addr, rogue) = rogue_listener(2, vec![0xAB; 64]);
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

/// One serialized frame, verbatim.
fn frame_bytes(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, frame).unwrap();
    buf
}

/// A syntactically valid response-ok frame answering `shard` of job 0.
fn ok_response_bytes_for(shard: usize, payload_len: usize) -> Vec<u8> {
    frame_bytes(&Frame {
        kind: FrameKind::RespOk,
        job_id: 0,
        worker_id: shard as u64,
        compute_us: 0,
        delay_us: 0,
        payload: vec![9u8; payload_len].into(),
    })
}

#[test]
fn truncated_response_frame_is_a_clean_per_job_failure() {
    // replies with a valid frame cut mid-payload, then closes
    let mut reply = ok_response_bytes_for(1, 100);
    reply.truncate(wire::HEADER_LEN + 12);
    let (addr, rogue) = rogue_listener(2, reply);
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn oversized_declared_payload_is_a_clean_per_job_failure() {
    // a syntactically valid response header declaring a 1 TiB payload: the
    // reader must reject it before allocating and fail the link over
    let mut reply = ok_response_bytes_for(1, 0);
    reply[40..48].copy_from_slice(&(1u64 << 40).to_le_bytes());
    let (addr, rogue) = rogue_listener(2, reply);
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn hello_claiming_a_foreign_id_is_rejected_as_rogue() {
    // The rogue sits in slot 1 but echoes a hello claiming to be worker 0:
    // connection index is the authoritative identity, so the master must
    // kill the link instead of believing the claim.
    let (addr, rogue) = rogue_listener(1, frame_bytes(&Frame::hello(0)));
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn unsolicited_response_is_rejected_as_rogue() {
    // The rogue (slot 1, owed only shard 1 of job 0) answers for shard 0 —
    // work it was never sent. The reader validates responses against the
    // link's own outstanding set, so impersonating another worker's shard
    // kills the link and the shard it actually owed fail-stops.
    let (addr, rogue) = rogue_listener(2, ok_response_bytes_for(0, 16));
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn immediate_disconnect_fails_jobs_at_dispatch() {
    // the rogue accepts and closes without reading anything: by the time
    // jobs are submitted the link is (or is about to be) dead; either the
    // reader's drain or the writer's synthesized report fails the job —
    // never a hang, never a panic.
    let (addr, rogue) = rogue_listener(0, Vec::new());
    assert_rogue_degrades_to_fail_stop(addr, rogue);
}

#[test]
fn connect_to_unused_port_errors_after_retries() {
    // bind-then-drop guarantees the port is closed; connect must give up
    // with a useful error, not spin forever (bounded retry budget).
    let port = {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.local_addr().unwrap().port()
    };
    let endpoints = vec![format!("127.0.0.1:{port}")];
    let err = Coordinator::connect_tcp(&endpoints).unwrap_err();
    assert!(err.to_string().contains("refused"), "{err}");
}

#[test]
fn daemon_outlives_a_rogue_coordinator_then_serves_real_jobs() {
    // A peer that speaks garbage at a daemon must only cost that
    // connection; a real coordinator connecting next is served normally.
    let daemon =
        WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::None, 3, 2).unwrap();
    {
        let mut s = TcpStream::connect(daemon.addr()).unwrap();
        s.write_all(&[0x5A; 128]).unwrap();
        // wait for the daemon to reject the connection (it closes; EOF here)
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    let mut coord = Coordinator::connect_tcp(&[daemon.addr()]).unwrap();
    let (got, _) = coord.submit(vec![vec![7u8; 12]], 1).unwrap().wait().unwrap();
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].payload, vec![7u8; 12]);
    coord.shutdown();
    daemon.join().unwrap();
}
