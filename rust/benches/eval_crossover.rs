//! Ablation bench (DESIGN.md §4.4): naive vs subproduct-tree multipoint
//! evaluation/interpolation over GR(2^64, 4) — Lemma II.1's asymptotics vs
//! the small-N constants the experiments actually live in. Prints the
//! crossover. Also writes `BENCH_eval_crossover.json`.

use gr_cdmm::ring::eval::{
    eval_many_fast, eval_many_naive, interpolate_fast, interpolate_naive,
};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::traits::Ring;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::bench::{black_box, write_bench_json, Bencher};
use gr_cdmm::util::json::Json;
use gr_cdmm::util::rng::Rng64;

fn main() {
    let ring = Extension::new(Zq::z2e(64), 4);
    let b = Bencher::from_env();
    let mut rng = Rng64::seeded(47);
    let mut report: Vec<Json> = Vec::new();
    println!("# eval/interp crossover over {}\n", ring.name());
    for n in [4usize, 8, 16, 32, 64, 128, 256] {
        // need n exceptional points: 16^k >= n ⇒ widen the tower if needed
        let m_needed = (n as f64).log(16.0).ceil().max(1.0) as usize;
        let ring = Extension::new(Zq::z2e(64), 4.max(m_needed * 4));
        let pts = ring.exceptional_points(n).unwrap();
        let f: Vec<_> = (0..n).map(|_| ring.random(&mut rng)).collect();
        let ys = eval_many_naive(&ring, &f, &pts);
        report.push(
            b.bench(&format!("eval_naive   n={n}"), || {
                black_box(eval_many_naive(&ring, &f, &pts));
            })
            .to_json(),
        );
        report.push(
            b.bench(&format!("eval_fast    n={n}"), || {
                black_box(eval_many_fast(&ring, &f, &pts));
            })
            .to_json(),
        );
        report.push(
            b.bench(&format!("interp_naive n={n}"), || {
                black_box(interpolate_naive(&ring, &pts, &ys));
            })
            .to_json(),
        );
        report.push(
            b.bench(&format!("interp_fast  n={n}"), || {
                black_box(interpolate_fast(&ring, &pts, &ys));
            })
            .to_json(),
        );
        println!();
    }
    match write_bench_json("eval_crossover", &Json::Arr(report)) {
        Ok(p) => println!("(json: {})", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
