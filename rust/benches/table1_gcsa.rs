//! Bench: Table 1 — GCSA vs Batch-EP_RMFE over a Galois ring.
//! Analytic rows for every κ | n, plus the measured head-to-head at the
//! runnable `uvw = 1, κ = n` point (CSA). Also writes
//! `BENCH_table1_gcsa.json`.

use gr_cdmm::experiments::table1::{
    analytic_rows, measured_point, render_analytic, render_measured,
};
use gr_cdmm::util::bench::write_bench_json;
use gr_cdmm::util::json::Json;

fn main() {
    println!("# Table 1 — batch-coded matmul over Galois ring: GCSA vs Batch-EP_RMFE\n");
    println!("## analytic (N=16, n=4, u=v=w=2, t=r=s=1000; per-mult amortized)\n");
    let rows = analytic_rows(16, 4, 2, 2, 2, 1000, 1000, 1000);
    println!("{}", render_analytic(&rows));
    let size = std::env::var("GR_CDMM_BENCH_SIZES")
        .ok()
        .and_then(|s| s.split(',').next().and_then(|x| x.trim().parse().ok()))
        .unwrap_or(128);
    println!("\n## measured at the runnable point (n=2 batch, {size}², Z_2^64)\n");
    let points = measured_point(2, size, 46).unwrap();
    println!("{}", render_measured(&points));

    let json = Json::obj()
        .set("analytic", Json::Arr(rows.iter().map(|r| r.to_json()).collect()))
        .set("measured", Json::Arr(points.iter().map(|p| p.to_json()).collect()));
    match write_bench_json("table1_gcsa", &json) {
        Ok(p) => println!("(json: {})", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
