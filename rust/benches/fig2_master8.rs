//! Bench: Figure 2 — master node computation time + communication volume,
//! 8 workers over GR(2^64, 3), u=v=2, w=1, n=2.
//! `GR_CDMM_BENCH_SIZES=2000,4000,...` and `GR_CDMM_BENCH_REPS` override.
//! Also writes `BENCH_fig2_master8.json`.

use gr_cdmm::codes::registry::SchemeConfig;
use gr_cdmm::experiments::figs::{records_to_json, render_master_view, sweep};
use gr_cdmm::util::bench::write_bench_json;

fn sizes_from_env(default: &[usize]) -> Vec<usize> {
    std::env::var("GR_CDMM_BENCH_SIZES")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let sizes = sizes_from_env(&[128, 256]);
    let reps = std::env::var("GR_CDMM_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let recs = sweep(&cfg, &sizes, reps, 42).unwrap();
    println!("# Figure 2 — master view, 8 workers, GR(2^64,3)\n");
    println!("{}", render_master_view(&recs));
    match write_bench_json("fig2_master8", &records_to_json(&recs)) {
        Ok(p) => println!("(json: {})", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
