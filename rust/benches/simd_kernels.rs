//! Perf bench: the runtime-dispatched base-ring slice kernels
//! (`ring::arch`) — reference vs generic vs native, per base ring:
//!
//! * `Z_{2^64}` (mask mode: wrapping u64 + mask — the AVX2/NEON target),
//! * odd `Z_{p^e}` (`p = 2^31−1`, `e = 2`: the Montgomery path that
//!   replaces the per-element `u128 %`),
//! * a `GF(2^8)`-style tower (`Extension` over `Z_2`, m = 8) driven
//!   through the plane-major matmul, i.e. the dispatch as the worker path
//!   actually reaches it.
//!
//! Before timing, every backend's output is asserted bit-identical to the
//! reference backend — the bench refuses to measure a wrong kernel. Each
//! row prints the median speedup over reference. Backends are forced via
//! `arch::with_backend` (the in-process equivalent of `GR_CDMM_SIMD`), so
//! one run covers every family the host supports; hosts without AVX2
//! simply have no `native` rows.
//!
//! `cargo bench --bench simd_kernels -- --smoke` runs a seconds-fast CI
//! subset. Results are also written to `BENCH_simd_kernels.json`.

use gr_cdmm::ring::arch::{available_backends, kernels_for, with_backend, Backend};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::PlaneMatrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::ring::Ring;
use gr_cdmm::util::bench::{black_box, throughput, write_bench_json, Bencher};
use gr_cdmm::util::json::Json;
use gr_cdmm::util::rng::Rng64;
use std::time::Duration;

fn ratio(reference: Duration, this: Duration) -> f64 {
    reference.as_secs_f64() / this.as_secs_f64().max(1e-12)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = if smoke { Bencher::new(0, 1) } else { Bencher::from_env() };
    let mut rng = Rng64::seeded(117);
    let backends = available_backends();
    let mut report: Vec<Json> = Vec::new();

    let names: Vec<&str> = backends.iter().map(|&bk| kernels_for(bk).name).collect();
    println!(
        "# SIMD base-ring kernels{} — backends: {}",
        if smoke { " (smoke)" } else { "" },
        names.join(", ")
    );

    let (axpy_len, n, tower_n) = if smoke { (1 << 12, 32, 24) } else { (1 << 18, 256, 96) };

    // ---- scalar Zq rings: mask mode and odd-modulus Montgomery mode ----
    let rings: [(&str, Zq); 2] =
        [("Z_2^64 (mask)", Zq::z2e(64)), ("Z_(2^31-1)^2 (montgomery)", Zq::new(2147483647, 2))];
    for (ring_name, zq) in &rings {
        println!("\n## {ring_name}");

        // axpy: acc += s·x over a flat slice
        let x: Vec<u64> = (0..axpy_len).map(|_| zq.random(&mut rng)).collect();
        let acc0: Vec<u64> = (0..axpy_len).map(|_| zq.random(&mut rng)).collect();
        let s = zq.random(&mut rng);
        let expect = with_backend(Backend::Reference, || {
            let mut acc = acc0.clone();
            zq.slice_axpy_assign(&mut acc, &s, &x);
            acc
        });
        let mut ref_median = Duration::ZERO;
        for &bk in &backends {
            let got = with_backend(bk, || {
                let mut acc = acc0.clone();
                zq.slice_axpy_assign(&mut acc, &s, &x);
                acc
            });
            assert_eq!(got, expect, "{ring_name} axpy: {} != reference", kernels_for(bk).name);
            let mut acc = acc0.clone();
            let sample = b.bench(&format!("{ring_name} axpy {axpy_len} [{}]", names_of(bk)), || {
                with_backend(bk, || zq.slice_axpy_assign(&mut acc, &s, &x));
                black_box(&mut acc);
            });
            if bk == Backend::Reference {
                ref_median = sample.median;
            }
            println!(
                "    → {:.2} Gop/s, ×{:.2} vs reference",
                throughput(2.0 * axpy_len as f64, sample.median) / 1e9,
                ratio(ref_median, sample.median)
            );
            report.push(sample.to_json());
        }

        // matmul: c += a·b at n³
        let a = Matrix::random(zq, n, n, &mut rng);
        let bm = Matrix::random(zq, n, n, &mut rng);
        let expect = with_backend(Backend::Reference, || Matrix::matmul(zq, &a, &bm));
        for &bk in &backends {
            let got = with_backend(bk, || Matrix::matmul(zq, &a, &bm));
            assert_eq!(got, expect, "{ring_name} matmul: {} != reference", kernels_for(bk).name);
            let sample = b.bench(&format!("{ring_name} matmul {n}³ [{}]", names_of(bk)), || {
                black_box(with_backend(bk, || Matrix::matmul(zq, &a, &bm)));
            });
            if bk == Backend::Reference {
                ref_median = sample.median;
            }
            println!(
                "    → {:.2} Gop/s, ×{:.2} vs reference",
                throughput(2.0 * (n as f64).powi(3), sample.median) / 1e9,
                ratio(ref_median, sample.median)
            );
            report.push(sample.to_json());
        }
    }

    // ---- GF(2^8)-style tower through the plane-major worker kernel ----
    println!("\n## GF(2^8) tower (Extension over Z_2, m=8), plane-major matmul");
    let ext = Extension::new(Zq::z2e(1), 8);
    let a = Matrix::random(&ext, tower_n, tower_n, &mut rng);
    let bm = Matrix::random(&ext, tower_n, tower_n, &mut rng);
    let pa = PlaneMatrix::from_aos(&ext, &a);
    let pb = PlaneMatrix::from_aos(&ext, &bm);
    let expect =
        with_backend(Backend::Reference, || PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1));
    let mut ref_median = Duration::ZERO;
    for &bk in &backends {
        let got = with_backend(bk, || PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1));
        assert_eq!(got, expect, "tower matmul: {} != reference", kernels_for(bk).name);
        let sample = b.bench(&format!("GF(2^8) plane matmul {tower_n}³ [{}]", names_of(bk)), || {
            black_box(with_backend(bk, || PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1)));
        });
        if bk == Backend::Reference {
            ref_median = sample.median;
        }
        println!(
            "    → {:.3} Gext-op/s, ×{:.2} vs reference",
            throughput(2.0 * (tower_n as f64).powi(3), sample.median) / 1e9,
            ratio(ref_median, sample.median)
        );
        report.push(sample.to_json());
    }

    match write_bench_json("simd_kernels", &Json::Arr(report)) {
        Ok(p) => println!("\n(json: {})", p.display()),
        Err(e) => eprintln!("\n(json write failed: {e})"),
    }
}

/// The kernel-family name a backend resolves to on this host (e.g.
/// `native` → `native-avx2`).
fn names_of(bk: Backend) -> &'static str {
    kernels_for(bk).name
}
