//! Perf bench: the worker-node hot path — u64 matmul and GR(2^64, m) matmul,
//! native rust kernels vs (optionally) the AOT XLA artifact. This is the
//! §Perf L3 measurement target in EXPERIMENTS.md.

use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::ext_matrix_to_planes;
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::bench::{black_box, throughput, Bencher};
use gr_cdmm::util::rng::Rng64;

fn main() {
    let b = Bencher::from_env();
    let mut rng = Rng64::seeded(48);
    let zq = Zq::z2e(64);

    println!("# worker hot-path kernels\n## native u64 matmul");
    for n in [64usize, 128, 256, 512] {
        let a = Matrix::random(&zq, n, n, &mut rng);
        let bm = Matrix::random(&zq, n, n, &mut rng);
        let s = b.bench(&format!("u64 matmul {n}³"), || {
            black_box(Matrix::matmul(&zq, &a, &bm));
        });
        let ops = 2.0 * (n as f64).powi(3);
        println!("    → {:.2} Gop/s", throughput(ops, s.median) / 1e9);
    }

    println!("\n## native GR(2^64, m) matmul (worker share product)");
    for m in [3usize, 4] {
        let ext = Extension::new(zq.clone(), m);
        let n = 128;
        let a = Matrix::random(&ext, n, n, &mut rng);
        let bm = Matrix::random(&ext, n, n, &mut rng);
        let s = b.bench(&format!("GR m={m} matmul {n}³"), || {
            black_box(Matrix::matmul(&ext, &a, &bm));
        });
        // each ext mul ≈ m² u64 mul-adds + reduction
        let ops = 2.0 * (n as f64).powi(3) * (m * m) as f64;
        println!("    → {:.2} effective u64 Gop/s", throughput(ops, s.median) / 1e9);
    }

    println!("\n## AOT XLA artifact (same task through PJRT)");
    match XlaRuntime::open_default() {
        Err(e) => println!("  skipped: {e}"),
        Ok(rt) => {
            if let Some(spec) = rt.find_spec(3, 128, 256, 128) {
                let artifact = rt.load(&spec.name.clone()).unwrap();
                let ext = Extension::new(zq.clone(), 3);
                let a = Matrix::random(&ext, 128, 256, &mut rng);
                let bm = Matrix::random(&ext, 256, 128, &mut rng);
                let ap = ext_matrix_to_planes(3, &a);
                let bp = ext_matrix_to_planes(3, &bm);
                b.bench("xla GR m=3 128x256x128", || {
                    black_box(
                        artifact
                            .run_u64(&[
                                (ap.clone(), vec![3, 128, 256]),
                                (bp.clone(), vec![3, 256, 128]),
                            ])
                            .unwrap(),
                    );
                });
            } else {
                println!("  m=3 artifact missing (make artifacts)");
            }
        }
    }
}
