//! Perf bench: the worker-node hot path — u64 matmul and GR(2^64, m) matmul
//! in both representations (AoS `Matrix<Vec<u64>>` baseline vs the
//! plane-major `PlaneMatrix` the wire/worker path actually uses), plus
//! (optionally) the AOT XLA artifact. This is the §Perf L3 measurement
//! target in EXPERIMENTS.md.
//!
//! The GR section covers every Table 1 / §V.A extension degree (m = 3 for
//! N=8, m = 4 for N=16, m = 5 for N=32) and prints the plane/AoS median
//! ratio — the plane-major kernel must be no slower at every config.
//!
//! `cargo bench --bench matmul_kernels -- --smoke` runs a seconds-fast CI
//! smoke subset. Results are also written to `BENCH_matmul_kernels.json`.

use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::PlaneMatrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::ext_matrix_to_planes;
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::bench::{black_box, throughput, write_bench_json, Bencher};
use gr_cdmm::util::json::Json;
use gr_cdmm::util::rng::Rng64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = if smoke { Bencher::new(0, 1) } else { Bencher::from_env() };
    let mut rng = Rng64::seeded(48);
    let zq = Zq::z2e(64);
    let mut report: Vec<Json> = Vec::new();

    println!("# worker hot-path kernels{}\n## native u64 matmul", if smoke { " (smoke)" } else { "" });
    let u64_sizes: &[usize] = if smoke { &[64] } else { &[64, 128, 256, 512] };
    for &n in u64_sizes {
        let a = Matrix::random(&zq, n, n, &mut rng);
        let bm = Matrix::random(&zq, n, n, &mut rng);
        let s = b.bench(&format!("u64 matmul {n}³"), || {
            black_box(Matrix::matmul(&zq, &a, &bm));
        });
        let ops = 2.0 * (n as f64).powi(3);
        println!("    → {:.2} Gop/s", throughput(ops, s.median) / 1e9);
        report.push(s.to_json());
    }

    println!("\n## GR(2^64, m) worker share product: AoS baseline vs plane-major");
    let n = if smoke { 32 } else { 128 };
    for m in [3usize, 4, 5] {
        let ext = Extension::new(zq.clone(), m);
        let a = Matrix::random(&ext, n, n, &mut rng);
        let bm = Matrix::random(&ext, n, n, &mut rng);
        let pa = PlaneMatrix::from_aos(&ext, &a);
        let pb = PlaneMatrix::from_aos(&ext, &bm);
        // sanity: the two kernels agree bit-for-bit
        assert_eq!(
            PlaneMatrix::matmul(&ext, &pa, &pb),
            PlaneMatrix::from_aos(&ext, &Matrix::matmul(&ext, &a, &bm)),
            "plane-major kernel must match the AoS kernel (m={m})"
        );
        let aos = b.bench(&format!("GR m={m} AoS matmul {n}³"), || {
            black_box(Matrix::matmul(&ext, &a, &bm));
        });
        let plane = b.bench(&format!("GR m={m} plane-major matmul {n}³"), || {
            black_box(PlaneMatrix::matmul(&ext, &pa, &pb));
        });
        // each ext mul ≈ m² u64 mul-adds + reduction
        let ops = 2.0 * (n as f64).powi(3) * (m * m) as f64;
        println!(
            "    → plane-major {:.2} effective u64 Gop/s; plane/AoS median ratio {:.3}",
            throughput(ops, plane.median) / 1e9,
            plane.median.as_secs_f64() / aos.median.as_secs_f64().max(1e-12)
        );
        report.push(aos.to_json());
        report.push(plane.to_json());
    }

    if !smoke {
        println!("\n## AOT XLA artifact (same task through PJRT)");
        match XlaRuntime::open_default() {
            Err(e) => println!("  skipped: {e}"),
            Ok(rt) => {
                if let Some(spec) = rt.find_spec(3, 128, 256, 128) {
                    let artifact = rt.load(&spec.name.clone()).unwrap();
                    let ext = Extension::new(zq.clone(), 3);
                    let a = Matrix::random(&ext, 128, 256, &mut rng);
                    let bm = Matrix::random(&ext, 256, 128, &mut rng);
                    let ap = ext_matrix_to_planes(3, &a);
                    let bp = ext_matrix_to_planes(3, &bm);
                    let s = b.bench("xla GR m=3 128x256x128", || {
                        black_box(
                            artifact
                                .run_u64(&[
                                    (ap.clone(), vec![3, 128, 256]),
                                    (bp.clone(), vec![3, 256, 128]),
                                ])
                                .unwrap(),
                        );
                    });
                    report.push(s.to_json());
                } else {
                    println!("  m=3 artifact missing (make artifacts)");
                }
            }
        }
    }

    match write_bench_json("matmul_kernels", &Json::Arr(report)) {
        Ok(p) => println!("\n(json: {})", p.display()),
        Err(e) => eprintln!("\n(json write failed: {e})"),
    }
}
