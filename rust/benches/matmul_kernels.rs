//! Perf bench: the worker-node hot path — u64 matmul and GR(2^64, m) matmul
//! across three dimensions: the AoS `Matrix<Vec<u64>>` baseline, the
//! sequential plane-major `PlaneMatrix` kernel, and the scoped-thread
//! parallel plane kernel the wire/worker path actually uses (row-panel
//! split over `GR_CDMM_THREADS`, default all cores), plus (optionally) the
//! AOT XLA artifact. This is the §Perf L3 measurement target in
//! EXPERIMENTS.md.
//!
//! The GR section covers every Table 1 / §V.A extension degree (m = 3 for
//! N=8, m = 4 for N=16, m = 5 for N=32) and prints the plane/AoS and
//! parallel/sequential median ratios — the plane-major kernel must be no
//! slower than AoS at every config, and the parallel kernel must beat
//! sequential for threads ≥ 2 at the Table-1 shapes.
//!
//! `cargo bench --bench matmul_kernels -- --smoke` runs a seconds-fast CI
//! smoke subset. Results are also written to `BENCH_matmul_kernels.json`.

use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::{slice_matmul_acc_threads, PlaneMatrix};
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::ext_matrix_to_planes;
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::bench::{black_box, throughput, write_bench_json, Bencher};
use gr_cdmm::util::json::Json;
use gr_cdmm::util::parallel;
use gr_cdmm::util::rng::Rng64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = if smoke { Bencher::new(0, 1) } else { Bencher::from_env() };
    let mut rng = Rng64::seeded(48);
    let zq = Zq::z2e(64);
    let threads = parallel::configured_threads();
    let mut report: Vec<Json> = Vec::new();

    println!(
        "# worker hot-path kernels{} ({threads} threads)\n## native u64 matmul",
        if smoke { " (smoke)" } else { "" }
    );
    let u64_sizes: &[usize] = if smoke { &[64] } else { &[64, 128, 256, 512] };
    for &n in u64_sizes {
        let a = Matrix::random(&zq, n, n, &mut rng);
        let bm = Matrix::random(&zq, n, n, &mut rng);
        let s = b.bench(&format!("u64 matmul {n}³"), || {
            black_box(Matrix::matmul(&zq, &a, &bm));
        });
        let par = b.bench(&format!("u64 matmul {n}³ ({threads}T row panels)"), || {
            let mut c = vec![0u64; n * n];
            slice_matmul_acc_threads(&zq, &mut c, &a.data, &bm.data, n, n, n, threads);
            black_box(c);
        });
        let ops = 2.0 * (n as f64).powi(3);
        println!(
            "    → {:.2} Gop/s sequential; par/seq median ratio {:.3}",
            throughput(ops, s.median) / 1e9,
            par.median.as_secs_f64() / s.median.as_secs_f64().max(1e-12)
        );
        report.push(s.to_json());
        report.push(par.to_json());
    }

    println!("\n## GR(2^64, m) worker share product: AoS vs plane-major vs parallel");
    let n = if smoke { 32 } else { 256 };
    for m in [3usize, 4, 5] {
        let ext = Extension::new(zq.clone(), m);
        let a = Matrix::random(&ext, n, n, &mut rng);
        let bm = Matrix::random(&ext, n, n, &mut rng);
        let pa = PlaneMatrix::from_aos(&ext, &a);
        let pb = PlaneMatrix::from_aos(&ext, &bm);
        // sanity: all three kernels agree bit-for-bit
        let seq_c = PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1);
        assert_eq!(
            seq_c,
            PlaneMatrix::from_aos(&ext, &Matrix::matmul(&ext, &a, &bm)),
            "plane-major kernel must match the AoS kernel (m={m})"
        );
        assert_eq!(
            PlaneMatrix::matmul_threads(&ext, &pa, &pb, threads),
            seq_c,
            "parallel kernel must be bit-identical to sequential (m={m})"
        );
        let aos = b.bench(&format!("GR m={m} AoS matmul {n}³"), || {
            black_box(Matrix::matmul(&ext, &a, &bm));
        });
        let plane = b.bench(&format!("GR m={m} plane-major matmul {n}³ (1T)"), || {
            black_box(PlaneMatrix::matmul_threads(&ext, &pa, &pb, 1));
        });
        let par = b.bench(&format!("GR m={m} plane-major matmul {n}³ ({threads}T)"), || {
            black_box(PlaneMatrix::matmul_threads(&ext, &pa, &pb, threads));
        });
        // each ext mul ≈ m² u64 mul-adds + reduction
        let ops = 2.0 * (n as f64).powi(3) * (m * m) as f64;
        println!(
            "    → parallel {:.2} effective u64 Gop/s; plane/AoS ratio {:.3}; par/seq ratio {:.3}",
            throughput(ops, par.median) / 1e9,
            plane.median.as_secs_f64() / aos.median.as_secs_f64().max(1e-12),
            par.median.as_secs_f64() / plane.median.as_secs_f64().max(1e-12)
        );
        report.push(aos.to_json());
        report.push(plane.to_json());
        report.push(par.to_json());
    }

    if !smoke {
        println!("\n## AOT XLA artifact (same task through PJRT)");
        match XlaRuntime::open_default() {
            Err(e) => println!("  skipped: {e}"),
            Ok(rt) => {
                if let Some(spec) = rt.find_spec(3, 128, 256, 128) {
                    let artifact = rt.load(&spec.name.clone()).unwrap();
                    let ext = Extension::new(zq.clone(), 3);
                    let a = Matrix::random(&ext, 128, 256, &mut rng);
                    let bm = Matrix::random(&ext, 256, 128, &mut rng);
                    let ap = ext_matrix_to_planes(3, &a);
                    let bp = ext_matrix_to_planes(3, &bm);
                    let s = b.bench("xla GR m=3 128x256x128", || {
                        black_box(
                            artifact
                                .run_u64(&[
                                    (ap.clone(), vec![3, 128, 256]),
                                    (bp.clone(), vec![3, 256, 128]),
                                ])
                                .unwrap(),
                        );
                    });
                    report.push(s.to_json());
                } else {
                    println!("  m=3 artifact missing (make artifacts)");
                }
            }
        }
    }

    match write_bench_json("matmul_kernels", &Json::Arr(report)) {
        Ok(p) => println!("\n(json: {})", p.display()),
        Err(e) => eprintln!("\n(json write failed: {e})"),
    }
}
