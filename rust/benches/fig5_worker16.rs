//! Bench: Figure 5 — per-worker computation time + communication volume,
//! 16 workers over GR(2^64, 4). Also writes `BENCH_fig5_worker16.json`.

use gr_cdmm::codes::registry::SchemeConfig;
use gr_cdmm::experiments::figs::{records_to_json, render_worker_view, sweep};
use gr_cdmm::util::bench::write_bench_json;

fn main() {
    let sizes: Vec<usize> = std::env::var("GR_CDMM_BENCH_SIZES")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![128, 256]);
    let reps = std::env::var("GR_CDMM_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let cfg = SchemeConfig::for_workers(16).unwrap();
    let recs = sweep(&cfg, &sizes, reps, 45).unwrap();
    println!("# Figure 5 — worker view, 16 workers, GR(2^64,4)\n");
    println!("{}", render_worker_view(&recs));
    match write_bench_json("fig5_worker16", &records_to_json(&recs)) {
        Ok(p) => println!("(json: {})", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
