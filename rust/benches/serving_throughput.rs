//! Bench: serving throughput — the pipelined multi-job coordinator vs the
//! sequential submit+wait baseline, per the ISSUE-3 acceptance setup: 8
//! workers, two fixed-slow stragglers, ≥ 4 jobs in flight — now measured on
//! **three transports**: the in-process channel pool, real TCP loopback
//! daemons, and the shared-memory transport (control on TCP, payloads
//! through file-backed rings). Same straggler draws across the triple, so
//! the rows price the wire itself: framing + socket syscalls + copies.
//!
//! Each row also reports the memory-discipline probes (pool hit ratio,
//! large allocations, copied bytes per job), and a final pooled-vs-unpooled
//! pair re-runs one row with the buffer pool disabled (`GR_CDMM_POOL_CAP=0`
//! operating point) to price what pooling buys.
//!
//! 16 jobs per pass: with the two stragglers never among the first `R = 4`,
//! the responding subsets are drawn from `C(6,4) = 15` possibilities, so 16
//! decodes guarantee at least one decode-plan cache hit by pigeonhole.
//!
//! Every row also runs the **prepared** (encode-once) pass: one fixed `A`
//! across the stream, its share halves staged on the workers once, each job
//! shipping only its B-halves. The pass itself asserts the proof
//! obligations — exactly one A-side encode for the whole stream and per-job
//! upload equal to the B-halves alone (≈ ½ the full share for square
//! shapes) — and the prepared-vs-pipelined column prices what encode-once
//! buys on top of pipelining.
//!
//! `cargo bench --bench serving_throughput -- --smoke` runs the seconds-fast
//! CI subset. Writes `BENCH_serving_throughput.json` (per scheme × size ×
//! transport: sequential, pipelined and prepared jobs/s, speedups, byte
//! volumes full-share vs B-only vs staged, plan-cache and prepared-store
//! counters, verification).

use gr_cdmm::coordinator::{CorruptionModel, StragglerModel};
use gr_cdmm::experiments::serving::{
    records_to_json, render, run, ServeConfig, ServeTransport,
};
use gr_cdmm::util::bench::write_bench_json;
use gr_cdmm::util::bytepool::BytePool;
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let sizes: &[usize] = if smoke { &[64] } else { &[96, 128] };
    let schemes: &[&str] = if smoke { &["ep-rmfe-1"] } else { &["ep", "ep-rmfe-1", "ep-rmfe-2"] };
    let straggler = StragglerModel::fixed_slow([0, 1], Duration::from_millis(25));

    println!(
        "# serving throughput — 8 workers, workers 0/1 slow by 25ms, 16 jobs, 4 in flight, \
         channel vs tcp-loopback vs shm{}\n",
        if smoke { " (smoke)" } else { "" }
    );
    let mut records = Vec::new();
    for &scheme in schemes {
        for &size in sizes {
            for transport in [
                ServeTransport::InProcess,
                ServeTransport::TcpLoopback,
                ServeTransport::ShmLoopback,
            ] {
                let cfg = ServeConfig {
                    scheme: scheme.to_string(),
                    n_workers: 8,
                    size,
                    jobs: 16,
                    inflight: 4,
                    straggler: straggler.clone(),
                    corrupt: CorruptionModel::None,
                    seed: 42,
                    verify: true,
                    // The verified pass replaces the throughput passes, so
                    // it is exercised by serve/CI, not benched here.
                    verify_products: false,
                    transport,
                    speculate: false,
                    elastic: false,
                    // Every bench scheme has independent operand encodes, so
                    // every row carries the encode-once pass (and its
                    // in-run assertions: one A-encode, B-only upload).
                    prepared: true,
                };
                let label = cfg.transport.label();
                // A failed run must fail the bench (and the CI smoke step),
                // not just print and keep going.
                let rec = run(&cfg).unwrap_or_else(|e| {
                    panic!("{scheme}@{size}/{label}: serving run failed: {e}")
                });
                assert!(rec.verified, "{scheme}@{size}/{label}: decode mismatch");
                records.push(rec);
            }
        }
    }
    println!("{}", render(&records));
    for rec in &records {
        println!(
            "{}@{} [{}]: pipelined {:.2} jobs/s vs sequential {:.2} jobs/s ({:.2}x), \
             plan cache {}/{} hits",
            rec.scheme,
            rec.size,
            rec.transport,
            rec.pipe_jobs_per_s,
            rec.seq_jobs_per_s,
            rec.speedup,
            rec.plan_cache_hits,
            rec.plan_cache_hits + rec.plan_cache_misses,
        );
        println!(
            "{}@{} [{}]: prepared {:.2} jobs/s ({:.2}x over pipelined), per-job upload \
             {} B → {} B (B-halves only), A-halves staged once ({} B), steady A-encodes {}",
            rec.scheme,
            rec.size,
            rec.transport,
            rec.prep_jobs_per_s,
            rec.prep_speedup,
            rec.pipe_upload_bytes / rec.jobs as u64,
            rec.prep_upload_bytes / rec.jobs as u64,
            rec.staged_upload_bytes,
            rec.steady_a_encodes,
        );
        println!(
            "{}@{} [{}]: memory discipline — pool hits {}/{}, large allocs {}, \
             copied {} B/job",
            rec.scheme,
            rec.size,
            rec.transport,
            rec.pool_hits,
            rec.pool_hits + rec.pool_misses,
            rec.large_allocs,
            rec.copied_bytes / rec.jobs.max(1) as u64,
        );
    }
    // The headline transport-cost rows: pipelined channel vs pipelined TCP
    // vs pipelined shm at matching (scheme, size).
    for triple in records.chunks(3) {
        if let [chan, tcp, shm] = triple {
            println!(
                "{}@{}: transport cost channel {:.2} jobs/s vs tcp-loopback {:.2} jobs/s \
                 ({:.2}x) vs shm {:.2} jobs/s ({:.2}x)",
                chan.scheme,
                chan.size,
                chan.pipe_jobs_per_s,
                tcp.pipe_jobs_per_s,
                chan.pipe_jobs_per_s / tcp.pipe_jobs_per_s.max(1e-12),
                shm.pipe_jobs_per_s,
                chan.pipe_jobs_per_s / shm.pipe_jobs_per_s.max(1e-12),
            );
        }
    }

    // Pooled vs unpooled: re-run one channel row with the global pool
    // disabled (the `GR_CDMM_POOL_CAP=0` operating point) and price what
    // the buffer pool buys — the allocs-per-job delta is the whole story,
    // since a cap-0 pool misses every lease.
    let base_cfg = ServeConfig {
        scheme: schemes[0].to_string(),
        n_workers: 8,
        size: sizes[0],
        jobs: 16,
        inflight: 4,
        straggler: straggler.clone(),
        corrupt: CorruptionModel::None,
        seed: 42,
        verify: true,
        verify_products: false,
        transport: ServeTransport::InProcess,
        speculate: false,
        elastic: false,
        prepared: false,
    };
    let pooled = run(&base_cfg).expect("pooled comparison run failed");
    let saved_cap = BytePool::global().cap();
    BytePool::global().set_cap(0);
    let unpooled = run(&base_cfg).expect("unpooled comparison run failed");
    BytePool::global().set_cap(saved_cap);
    assert!(pooled.verified && unpooled.verified, "comparison runs must decode correctly");
    let jobs = base_cfg.jobs as u64;
    println!(
        "\npooled vs unpooled ({}@{}, channel, {} jobs): \
         allocs/job {:.1} → {:.1}, large allocs {} → {}, copied {} → {} B/job",
        base_cfg.scheme,
        base_cfg.size,
        jobs,
        pooled.pool_misses as f64 / jobs as f64,
        unpooled.pool_misses as f64 / jobs as f64,
        pooled.large_allocs,
        unpooled.large_allocs,
        pooled.copied_bytes / jobs,
        unpooled.copied_bytes / jobs,
    );
    records.push(pooled);
    records.push(unpooled);

    match write_bench_json("serving_throughput", &records_to_json(&records)) {
        Ok(p) => println!("\n(json: {})", p.display()),
        Err(e) => eprintln!("\n(json write failed: {e})"),
    }
}
