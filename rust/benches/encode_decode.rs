//! Perf bench: master-side encode/decode throughput per registry scheme,
//! through the erased byte facade (the exact path `main.rs`, the
//! experiments harness and the serving loop take).
//!
//! For every registry scheme at the §V.A 8-worker config this measures
//! encode (plan-driven sparse Horner fan-out over scoped threads) and the
//! steady-state **warm** decode (plan-cache hit: zero interpolation setup,
//! zero scalar-mul-table builds — asserted here via
//! [`gr_cdmm::ring::plane::scalar_table_builds`]), and reports the cold
//! decode (first subset, computes the plan) once for contrast.
//!
//! `cargo bench --bench encode_decode -- --smoke` is the seconds-fast CI
//! subset. Results are written to `BENCH_encode_decode.json`.

use gr_cdmm::codes::registry::{self, SchemeConfig, SCHEME_NAMES};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::scalar_table_builds;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::bench::{black_box, throughput, write_bench_json, Bencher};
use gr_cdmm::util::bytepool::PooledBuf;
use gr_cdmm::util::json::Json;
use gr_cdmm::util::parallel;
use gr_cdmm::util::rng::Rng64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = if smoke { Bencher::new(0, 1) } else { Bencher::from_env() };
    let size: usize = std::env::var("GR_CDMM_BENCH_SIZES")
        .ok()
        .and_then(|s| s.split(',').next().and_then(|x| x.trim().parse().ok()))
        .unwrap_or(if smoke { 32 } else { 256 });
    let threads = parallel::configured_threads();
    let cfg = SchemeConfig::for_workers(8).unwrap();
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(77);
    let mut report: Vec<Json> = Vec::new();

    println!(
        "# encode/decode throughput{} — N=8 config, {size}² inputs, {threads} threads",
        if smoke { " (smoke)" } else { "" }
    );
    for (name, _) in SCHEME_NAMES {
        let scheme = registry::build(name, &cfg).unwrap();
        let n = scheme.batch_size();
        let a: Vec<Vec<u8>> = (0..n)
            .map(|_| Matrix::random(&base, size, size, &mut rng).to_bytes(&base))
            .collect();
        let bb: Vec<Vec<u8>> = (0..n)
            .map(|_| Matrix::random(&base, size, size, &mut rng).to_bytes(&base))
            .collect();
        let enc = b.bench(&format!("{name} encode {size}²"), || {
            black_box(scheme.encode_bytes(&a, &bb).unwrap());
        });
        let payloads = scheme.encode_bytes(&a, &bb).unwrap();
        let rt = scheme.recovery_threshold();
        let responses: Vec<(usize, PooledBuf)> = (0..rt)
            .map(|i| (i, scheme.compute_bytes(&payloads[i]).unwrap()))
            .collect();
        let borrowed: Vec<(usize, &[u8])> =
            responses.iter().map(|(i, p)| (*i, p.as_slice())).collect();
        // First decode of this subset is cold: it computes and caches the
        // decode plan. Everything after is the steady state.
        let (cold, _) = Bencher::time_once(|| black_box(scheme.decode_bytes(&borrowed).unwrap()));
        // Zero-builds probe: the build counter is per-thread, so run one
        // warm decode pinned to this thread — any table rebuild is visible.
        let builds = parallel::with_threads(1, || {
            let before = scalar_table_builds();
            black_box(scheme.decode_bytes(&borrowed).unwrap());
            scalar_table_builds() - before
        });
        assert_eq!(
            builds, 0,
            "{name}: steady-state decode must not rebuild scalar-mul tables"
        );
        // Timed warm decodes run with the configured thread count.
        let dec = b.bench(&format!("{name} decode(warm) {size}²"), || {
            black_box(scheme.decode_bytes(&borrowed).unwrap());
        });
        let upload = scheme.upload_bytes(size, size, size) as f64;
        println!(
            "    → encode {:.1} MB/s upload; cold decode {cold:?}; warm/cold ratio {:.3}; \
             steady-state table builds 0 ✓",
            throughput(upload, enc.median) / 1e6,
            dec.median.as_secs_f64() / cold.as_secs_f64().max(1e-12)
        );
        report.push(enc.to_json());
        report.push(dec.to_json());
    }

    match write_bench_json("encode_decode", &Json::Arr(report)) {
        Ok(p) => println!("\n(json: {})", p.display()),
        Err(e) => eprintln!("\n(json write failed: {e})"),
    }
}
