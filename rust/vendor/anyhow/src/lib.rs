//! Offline polyfill of the [`anyhow`](https://crates.io/crates/anyhow) API
//! subset that `gr_cdmm` uses: [`Error`], [`Result`], and the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros.
//!
//! The build environment for this repository has no crates.io access, so the
//! real crate cannot be fetched; this ~100-line stand-in is API-compatible
//! for the subset in use and dependency-free. Differences from the real
//! crate, by design:
//!
//! * no backtrace capture and no `context()`/`chain()` — the error is a
//!   single eagerly formatted message (source chains are flattened with
//!   `": "` at conversion time);
//! * `{:#}` (alternate) formatting equals `{}` — callers only rely on both
//!   printing the message.
//!
//! To switch to the real `anyhow`, point the `anyhow` dependency of
//! `gr_cdmm` at a version requirement instead of this path — no source
//! changes are needed.

use std::fmt;

/// A boxed-message error type; the polyfill's stand-in for `anyhow::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (the polyfill's `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Like `anyhow`, convert from any standard error, flattening its source
/// chain into the message. `Error` itself deliberately does NOT implement
/// `std::error::Error`, which is what makes this blanket impl coherent
/// alongside the reflexive `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with this crate's [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string: `anyhow!("bad {x}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`]: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
/// `ensure!(cond)` uses the stringified condition as the message;
/// `ensure!(cond, "msg {x}")` formats like [`anyhow!`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn takes_two(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(1)
    }

    fn takes_one(x: usize) -> Result<()> {
        ensure!(x >= 1);
        Ok(())
    }

    fn bails() -> Result<()> {
        bail!("always {}", "fails");
    }

    #[test]
    fn macros_format_and_return() {
        assert_eq!(takes_two(true).unwrap(), 1);
        assert_eq!(takes_two(false).unwrap_err().to_string(), "flag was false");
        assert!(takes_one(1).is_ok());
        assert_eq!(
            takes_one(0).unwrap_err().to_string(),
            "condition failed: `x >= 1`"
        );
        assert_eq!(bails().unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn display_and_debug_and_alternate_agree() {
        let e = anyhow!("msg {}", 7);
        assert_eq!(format!("{e}"), "msg 7");
        assert_eq!(format!("{e:?}"), "msg 7");
        assert_eq!(format!("{e:#}"), "msg 7");
    }
}
