"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

Integer arithmetic ⇒ assertions are bit-exact (`array_equal`), not allclose.
Hypothesis sweeps shapes (including non-multiples of the block size) and both
supported dtypes.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_zq import matmul_zq, vmem_bytes
from compile.kernels.ref import matmul_zq_ref

jax.config.update("jax_enable_x64", True)


def rand_u(rng, shape, dtype):
    hi = np.iinfo(np.uint64).max if dtype == jnp.uint64 else np.iinfo(np.uint32).max
    return jnp.asarray(
        rng.integers(0, hi, size=shape, dtype=np.uint64).astype(
            np.uint64 if dtype == jnp.uint64 else np.uint32
        )
    )


@pytest.mark.parametrize("dtype", [jnp.uint64, jnp.uint32])
@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 32, 8), (128, 128, 128), (64, 256, 32)])
def test_matmul_matches_ref(dtype, shape):
    t, r, s = shape
    rng = np.random.default_rng(42)
    x = rand_u(rng, (t, r), dtype)
    y = rand_u(rng, (r, s), dtype)
    got = matmul_zq(x, y)
    want = matmul_zq_ref(x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wraparound_semantics():
    # (2^63)·2 ≡ 0 mod 2^64 — overflow must wrap, not saturate.
    x = jnp.array([[1 << 63]], dtype=jnp.uint64)
    y = jnp.array([[2]], dtype=jnp.uint64)
    assert int(matmul_zq(x, y)[0, 0]) == 0
    xm = jnp.array([[np.uint64(0xFFFFFFFFFFFFFFFF)]], dtype=jnp.uint64)
    assert int(matmul_zq(xm, y)[0, 0]) == 0xFFFFFFFFFFFFFFFE


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 24),
    r=st.integers(1, 24),
    s=st.integers(1, 24),
    seed=st.integers(0, 2**31),
    dtype=st.sampled_from([jnp.uint64, jnp.uint32]),
)
def test_matmul_hypothesis_shapes(t, r, s, seed, dtype):
    rng = np.random.default_rng(seed)
    x = rand_u(rng, (t, r), dtype)
    y = rand_u(rng, (r, s), dtype)
    np.testing.assert_array_equal(
        np.asarray(matmul_zq(x, y)), np.asarray(matmul_zq_ref(x, y))
    )


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 64, 128]),
    bn=st.sampled_from([8, 16, 64, 128]),
    bk=st.sampled_from([8, 16, 64, 128]),
)
def test_block_size_invariance(bm, bn, bk):
    # The tiling schedule must not change the numbers.
    rng = np.random.default_rng(7)
    x = rand_u(rng, (32, 48), jnp.uint64)
    y = rand_u(rng, (48, 16), jnp.uint64)
    got = matmul_zq(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(matmul_zq_ref(x, y)))


def test_vmem_budget_default_blocks():
    # DESIGN.md §Perf: default tiling must stay far below 16 MiB VMEM.
    assert vmem_bytes(128, 128, 128, 8) == 3 * 128 * 128 * 8
    assert vmem_bytes() < 16 * 1024 * 1024


def test_rejects_bad_dtypes():
    x = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(AssertionError):
        matmul_zq(x, x)
