"""AOT path: lowering produces parseable HLO text with the expected
signatures, and the emitted computation is numerically identical to the
oracle when re-executed through XLA."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels.ref import gr_matmul_ref, matmul_zq_ref
from compile.model import gr_worker_task, lower_task, spec, u64_matmul_task

jax.config.update("jax_enable_x64", True)


def test_u64_task_lowers_to_hlo_text():
    lowered = lower_task(u64_matmul_task(), (spec((8, 8)), spec((8, 8))))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "u64[8,8]" in text


def test_gr_task_lowers_with_planes():
    task, modulus = gr_worker_task(3)
    assert modulus == (1, 1, 0, 1)
    lowered = lower_task(task, (spec((3, 8, 8)), spec((3, 8, 8))))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "u64[3,8,8]" in text


def test_build_all_quick(tmp_path):
    manifest = aot.build_all(str(tmp_path), aot.QUICK_CONFIGS)
    assert len(manifest["artifacts"]) == len(aot.QUICK_CONFIGS)
    for art in manifest["artifacts"]:
        p = tmp_path / art["file"]
        assert p.exists(), art
        head = p.read_text()[:200]
        assert "HloModule" in head
    # manifest round-trips
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["artifacts"][0]["dtype"] == "uint64"


def test_lowered_u64_task_numerics_via_jit():
    # jit-execute the same task that gets lowered; bit-exact vs oracle.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 2**63, size=(16, 16), dtype=np.uint64))
    y = jnp.asarray(rng.integers(0, 2**63, size=(16, 16), dtype=np.uint64))
    (got,) = jax.jit(u64_matmul_task())(x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(matmul_zq_ref(x, y)))


def test_lowered_gr_task_numerics_via_jit():
    task, modulus = gr_worker_task(3)
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 2**63, size=(3, 8, 8), dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 2**63, size=(3, 8, 8), dtype=np.uint64))
    (got,) = jax.jit(task)(a, b)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(gr_matmul_ref(a, b, modulus))
    )
