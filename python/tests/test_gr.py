"""L2 correctness: GR(2^e, m) plane matmul vs the jnp oracle, and the
cross-language modulus contract with the rust ring layer."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gr_matmul import (
    find_irreducible_gf2,
    gr_matmul,
    is_irreducible_gf2,
)
from compile.kernels.ref import gr_matmul_ref

jax.config.update("jax_enable_x64", True)


def rand_planes(rng, m, rows, cols):
    return jnp.asarray(
        rng.integers(0, np.iinfo(np.uint64).max, size=(m, rows, cols), dtype=np.uint64)
    )


# --- modulus contract -------------------------------------------------------


def test_irreducibility_oracle():
    # x^2+x+1 = 0b111, x^2+1 = 0b101 = (x+1)^2, x^3+x+1 = 0b1011
    assert is_irreducible_gf2(0b111)
    assert not is_irreducible_gf2(0b101)
    assert is_irreducible_gf2(0b1011)
    assert not is_irreducible_gf2(0b1111)  # x^3+x^2+x+1 = (x+1)(x^2+1)


def test_canonical_moduli_match_rust():
    """These constants are asserted on the rust side too
    (rust/tests/integration_runtime.rs) — the AOT artifact and the rust
    Extension MUST agree on h(y) or plane reduction diverges."""
    assert find_irreducible_gf2(1) == [1, 1]  # y + 1
    assert find_irreducible_gf2(2) == [1, 1, 1]  # y² + y + 1
    assert find_irreducible_gf2(3) == [1, 1, 0, 1]  # y³ + y + 1
    assert find_irreducible_gf2(4) == [1, 1, 0, 0, 1]  # y⁴ + y + 1
    assert find_irreducible_gf2(5) == [1, 0, 1, 0, 0, 1]  # y⁵ + y² + 1


# --- GR matmul vs oracle ----------------------------------------------------


@pytest.mark.parametrize("m", [2, 3, 4])
def test_gr_matmul_matches_ref(m):
    modulus = tuple(find_irreducible_gf2(m))
    rng = np.random.default_rng(m)
    a = rand_planes(rng, m, 8, 12)
    b = rand_planes(rng, m, 12, 8)
    got = gr_matmul(a, b, modulus)
    want = gr_matmul_ref(a, b, modulus)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gr_matmul_identity():
    m = 3
    modulus = tuple(find_irreducible_gf2(m))
    rng = np.random.default_rng(9)
    a = rand_planes(rng, m, 6, 6)
    # identity in GR: plane 0 = I, higher planes = 0
    ident = jnp.stack(
        [jnp.eye(6, dtype=jnp.uint64)] + [jnp.zeros((6, 6), jnp.uint64)] * (m - 1)
    )
    got = gr_matmul(a, ident, modulus)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a))


def test_gr_matmul_scalar_case_reduces_to_u64():
    # m=1 with modulus y+1: single plane, plain u64 matmul.
    rng = np.random.default_rng(11)
    a = rand_planes(rng, 1, 5, 7)
    b = rand_planes(rng, 1, 7, 5)
    got = gr_matmul(a, b, (1, 1))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(a[0] @ b[0]))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 4),
    t=st.integers(1, 8),
    r=st.integers(1, 8),
    s=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_gr_matmul_hypothesis(m, t, r, s, seed):
    modulus = tuple(find_irreducible_gf2(m))
    rng = np.random.default_rng(seed)
    a = rand_planes(rng, m, t, r)
    b = rand_planes(rng, m, r, s)
    np.testing.assert_array_equal(
        np.asarray(gr_matmul(a, b, modulus)),
        np.asarray(gr_matmul_ref(a, b, modulus)),
    )


def test_gr_matmul_associativity():
    m = 3
    modulus = tuple(find_irreducible_gf2(m))
    rng = np.random.default_rng(13)
    a = rand_planes(rng, m, 4, 4)
    b = rand_planes(rng, m, 4, 4)
    c = rand_planes(rng, m, 4, 4)
    left = gr_matmul(gr_matmul(a, b, modulus), c, modulus)
    right = gr_matmul(a, gr_matmul(b, c, modulus), modulus)
    np.testing.assert_array_equal(np.asarray(left), np.asarray(right))
