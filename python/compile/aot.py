"""AOT compile path: lower the L2 worker tasks to HLO **text** artifacts.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` —
is the interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Run once via ``make artifacts``; the rust binary is self-contained after
that — Python never executes on the request path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]

Artifacts (shapes chosen to match the default experiment/example configs):
    matmul_u64_<t>x<r>x<s>.hlo.txt        plain Z_{2^64} block product
    worker_gr_m<m>_<t>x<r>x<s>.hlo.txt    GR(2^64, m) share product
    manifest.json                          shapes + moduli for the rust loader
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import gr_worker_task, lower_task, spec, u64_matmul_task  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


# (m, t, r, s) worker-share configurations:
#   m=1       → plain u64 matmul (also the L1 kernel smoke artifact)
#   m=3 cfg   → N=8 workers, u=v=2, w=1, matrices 256² → shares (128×256)(256×128)
#   m=4 cfg   → N=16 workers, u=v=w=2, matrices 256² → shares (128×128)(128×128)
DEFAULT_CONFIGS = [
    (1, 128, 128, 128),
    (3, 128, 256, 128),
    (4, 128, 128, 128),
]
QUICK_CONFIGS = [
    (1, 16, 16, 16),
    (3, 16, 32, 16),
    (4, 16, 16, 16),
]


def build_all(out_dir: str, configs) -> dict:
    manifest = {"artifacts": []}
    for m, t, r, s in configs:
        if m == 1:
            task = u64_matmul_task(use_pallas=True)
            name = f"matmul_u64_{t}x{r}x{s}"
            lowered = lower_task(task, (spec((t, r)), spec((r, s))))
            modulus = [0, 1]
        else:
            task, modulus = gr_worker_task(m, use_pallas=True)
            name = f"worker_gr_m{m}_{t}x{r}x{s}"
            lowered = lower_task(task, (spec((m, t, r)), spec((m, r, s))))
            modulus = list(modulus)
        emit(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "m": m,
                "t": t,
                "r": r,
                "s": s,
                "modulus": modulus,
                "dtype": "uint64",
            }
        )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny shapes (CI smoke)")
    ap.add_argument("--out", default=None, help="legacy single-file mode (ignored)")
    args = ap.parse_args()
    build_all(args.out_dir, QUICK_CONFIGS if args.quick else DEFAULT_CONFIGS)


if __name__ == "__main__":
    main()
