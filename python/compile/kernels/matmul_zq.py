"""Layer-1 Pallas kernel: tiled matrix multiplication over Z_{2^64} / Z_{2^32}.

The worker node's compute hot-spot (Section V: worker computation time) is an
integer matrix product with wrap-around modular semantics — `Z_{2^e}` is
"directly compatible with computation in real-life programming and computer
architectures" (§I), i.e. plain unsigned machine arithmetic.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the kernel tiles the product
over a `(M/bm, N/bn, K/bk)` grid; the output tile is revisited along the
contraction axis and accumulates in place (it stays resident in VMEM across
the `k` steps — the Pallas analogue of a scratch accumulator). Block defaults
128×128×128 give a VMEM footprint of 3·128²·8 B = 384 KiB, comfortably inside
a TensorCore's ~16 MiB VMEM. On this image Pallas MUST run `interpret=True`
(the CPU PJRT plugin cannot execute Mosaic custom-calls), so the kernel's
*structure* is the TPU artifact; numerics are bit-exact either way.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps):
    """One (bm × bn) output tile; grid axis 2 walks the contraction.

    The output block index map ignores the k axis, so `o_ref` addresses the
    same VMEM tile at every k step — zero it first, then accumulate partial
    products (wrap-around unsigned arithmetic = Z_{2^e} semantics).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )
    del k_steps  # structure kept for symmetry with scratch-based variants


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is ≤ pref (tiles must divide evenly)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


def matmul_zq(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """`x @ y` over Z_{2^e} (dtype uint32/uint64), Pallas-tiled.

    Shapes `(M, K) @ (K, N) -> (M, N)`. Block sizes are clamped to divisors
    of the dims so any shape works (the hypothesis suite sweeps odd shapes).
    """
    assert x.dtype == y.dtype, (x.dtype, y.dtype)
    assert x.dtype in (jnp.uint32, jnp.uint64), x.dtype
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    k_steps = k // bk

    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, y)


def vmem_bytes(bm: int = 128, bn: int = 128, bk: int = 128, itemsize: int = 8) -> int:
    """Estimated VMEM footprint of one grid step (x, y tiles + output tile).

    Used by the perf notes in DESIGN.md / EXPERIMENTS.md §Perf:
    128³ blocks at u64 → 384 KiB « 16 MiB VMEM.
    """
    return (bm * bk + bk * bn + bm * bn) * itemsize
