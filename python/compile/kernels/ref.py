"""Pure-jnp correctness oracles for the Pallas kernel and the GR matmul.

The pytest suite asserts bit-exact equality (integer arithmetic — no
tolerance) between the L1/L2 implementations and these references; the rust
integration tests close the loop by checking the AOT artifacts against the
rust-native ring kernels.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def matmul_zq_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Wrap-around unsigned matmul — XLA's native integer dot IS the Z_{2^e}
    semantics, so the reference is a plain jnp.matmul."""
    assert x.dtype in (jnp.uint32, jnp.uint64)
    return jnp.matmul(x, y)


def gr_matmul_ref(a_planes, b_planes, modulus):
    """Schoolbook polynomial matmul + reduction, all in jnp (no Pallas)."""
    m = a_planes.shape[0]
    dtype = a_planes.dtype
    t, s = a_planes.shape[1], b_planes.shape[2]
    planes = [jnp.zeros((t, s), dtype) for _ in range(2 * m - 1)]
    for i in range(m):
        for j in range(m):
            planes[i + j] = planes[i + j] + jnp.matmul(a_planes[i], b_planes[j])
    for k in range(2 * m - 2, m - 1, -1):
        for i in range(m):
            if modulus[i]:
                planes[k - m + i] = planes[k - m + i] - jnp.asarray(
                    modulus[i], dtype
                ) * planes[k]
    return jnp.stack(planes[:m])
