"""L1 Pallas kernels + L2 GR plane-decomposition for the worker task."""

from .matmul_zq import matmul_zq, vmem_bytes
from .gr_matmul import find_irreducible_gf2, gr_matmul, is_irreducible_gf2, make_worker_task
from . import ref

__all__ = [
    "matmul_zq",
    "vmem_bytes",
    "gr_matmul",
    "make_worker_task",
    "find_irreducible_gf2",
    "is_irreducible_gf2",
    "ref",
]
