"""Layer-2 compute graph: matrix multiplication over the Galois ring
GR(2^e, m) = Z_{2^e}[y]/(h(y)) as coefficient-plane integer matmuls.

An extension-ring matrix is stored as `m` coefficient planes of shape
`(rows, cols)` (plane k holds the y^k coefficients). The product is

    C_poly[k] = Σ_{i+j=k} A_i @ B_j            (k < 2m−1, plane matmuls)
    reduce by h:  for k from 2m−2 down to m:
        C_poly[k−m+i] −= h_i · C_poly[k]       (h monic)

All plane products go through the Pallas L1 kernel, so the whole worker task
lowers into a single HLO module (`aot.py`), executed from rust via PJRT.

The modulus h must match the rust side exactly: `find_irreducible_gf2` below
replicates `ring::irreducible::find_irreducible` (lexicographically-first
monic irreducible over GF(2), little-endian digit enumeration) and is
cross-checked against the rust constants in tests on both sides.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .matmul_zq import matmul_zq

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Deterministic modulus search (mirror of rust ring/irreducible.rs over GF(2))
# ---------------------------------------------------------------------------


def _gf2_poly_mulmod_x(a: int, m_poly: int, deg: int) -> int:
    """(a * x) mod m_poly over GF(2), bitmask representation."""
    a <<= 1
    if a >> deg & 1:
        a ^= m_poly
    return a & ((1 << deg) - 1) | (a & ~((1 << deg) - 1) and 0)


def _gf2_polymul(a: int, b: int) -> int:
    out = 0
    while b:
        if b & 1:
            out ^= a
        a <<= 1
        b >>= 1
    return out


def _gf2_polymod(a: int, m_poly: int) -> int:
    dm = m_poly.bit_length() - 1
    while a.bit_length() - 1 >= dm and a:
        a ^= m_poly << (a.bit_length() - 1 - dm)
    return a


def _gf2_powmod(a: int, n: int, m_poly: int) -> int:
    acc = 1
    a = _gf2_polymod(a, m_poly)
    while n:
        if n & 1:
            acc = _gf2_polymod(_gf2_polymul(acc, a), m_poly)
        n >>= 1
        if n:
            a = _gf2_polymod(_gf2_polymul(a, a), m_poly)
    return acc


def _gf2_gcd(a: int, b: int) -> int:
    while b:
        a, b = b, _gf2_polymod(a, b)
    return a


def _prime_factors(n: int) -> list[int]:
    out, d = [], 2
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            while n % d == 0:
                n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


def is_irreducible_gf2(poly: int) -> bool:
    """Rabin's test for a GF(2) polynomial in bitmask form (bit i = coeff x^i)."""
    m = poly.bit_length() - 1
    if m <= 0:
        return False
    if m == 1:
        return True
    x = 0b10
    # x^(2^m) ≡ x (mod poly)
    t = x
    for _ in range(m):
        t = _gf2_powmod(t, 2, poly)
    if t != _gf2_polymod(x, poly):
        return False
    for r in _prime_factors(m):
        k = m // r
        t = x
        for _ in range(k):
            t = _gf2_powmod(t, 2, poly)
        if _gf2_gcd(t ^ x, poly) != 1:
            return False
    return True


def find_irreducible_gf2(m: int) -> list[int]:
    """Little-endian coefficient list (length m+1) of the lexicographically-
    first monic irreducible of degree m over GF(2) — identical enumeration to
    rust `find_irreducible` (low coefficients as base-2 digits of a counter;
    candidates with zero constant term are skipped there via the quick
    screen, and they are never irreducible for m ≥ 2 anyway)."""
    idx = 0
    while True:
        coeffs = [(idx >> i) & 1 for i in range(m)] + [1]
        if coeffs[0] != 0:
            mask = sum(c << i for i, c in enumerate(coeffs))
            if is_irreducible_gf2(mask):
                return coeffs
        idx += 1
        assert idx < (1 << m) + 1, "no irreducible found (impossible)"


# ---------------------------------------------------------------------------
# GR matmul (plane decomposition + reduction)
# ---------------------------------------------------------------------------


def gr_matmul(
    a_planes: jax.Array,
    b_planes: jax.Array,
    modulus: tuple[int, ...],
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Multiply two GR(2^e, m) matrices given as coefficient planes.

    a_planes: (m, t, r) uint64/uint32; b_planes: (m, r, s); returns (m, t, s).
    `modulus` is the little-endian coefficient list of the monic degree-m
    defining polynomial (length m+1; only the low m entries are used).
    """
    m = a_planes.shape[0]
    assert b_planes.shape[0] == m
    assert len(modulus) == m + 1 and modulus[m] == 1, "modulus must be monic, len m+1"
    dtype = a_planes.dtype

    mm = (
        partial(matmul_zq, interpret=interpret)
        if use_pallas
        else lambda x, y: jnp.matmul(x, y)
    )

    # plane products: C_poly[k] = Σ_{i+j=k} A_i @ B_j  (k < 2m−1)
    t, s = a_planes.shape[1], b_planes.shape[2]
    planes = [jnp.zeros((t, s), dtype) for _ in range(2 * m - 1)]
    for i in range(m):
        for j in range(m):
            planes[i + j] = planes[i + j] + mm(a_planes[i], b_planes[j])

    # reduce modulo the monic modulus: y^k ≡ −Σ_i h_i y^{k−m+i}
    for k in range(2 * m - 2, m - 1, -1):
        c = planes[k]
        for i in range(m):
            if modulus[i]:
                # over Z_{2^e}: subtraction wraps; modulus coeffs are 0/1
                planes[k - m + i] = planes[k - m + i] - jnp.asarray(
                    modulus[i], dtype
                ) * c
    return jnp.stack(planes[:m])


def make_worker_task(m: int, modulus: tuple[int, ...], *, use_pallas: bool = True):
    """The worker-node computation as a jittable function of the two share
    plane-stacks — this is what `aot.py` lowers to the HLO artifact."""

    def task(a_planes, b_planes):
        return (gr_matmul(a_planes, b_planes, modulus, use_pallas=use_pallas),)

    return task
