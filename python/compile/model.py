"""Layer-2 model: the worker-node compute graphs that get AOT-lowered.

Two task families, matching what the rust coordinator dispatches:

* ``u64 matmul`` — the `Z_{2^64}` product of two share blocks (the `d=1`,
  `m=1` degenerate case, and the building block of everything else);
* ``GR(2^64, m) matmul`` — the extension-ring share product as `m²`
  coefficient-plane Pallas matmuls + modulus reduction (`kernels.gr_matmul`).

Each task is a pure function of its two inputs with every shape static, so
`aot.py` can lower it once per configuration and the rust runtime can load
the resulting HLO text and execute it via PJRT with zero Python at runtime.
"""

import jax
import jax.numpy as jnp

from .kernels.gr_matmul import find_irreducible_gf2, make_worker_task
from .kernels.matmul_zq import matmul_zq

jax.config.update("jax_enable_x64", True)


def u64_matmul_task(use_pallas: bool = True):
    """(t, r) @ (r, s) over Z_{2^64}."""

    def task(x, y):
        if use_pallas:
            return (matmul_zq(x, y),)
        return (jnp.matmul(x, y),)

    return task


def gr_worker_task(m: int, use_pallas: bool = True):
    """GR(2^64, m) share product, modulus = the canonical (rust-matching)
    lexicographically-first irreducible of degree m over GF(2)."""
    modulus = tuple(find_irreducible_gf2(m))
    return make_worker_task(m, modulus, use_pallas=use_pallas), modulus


def lower_task(task, arg_specs):
    """jit + lower with static shapes; returns the Lowered object."""
    return jax.jit(task).lower(*arg_specs)


def spec(shape, dtype=jnp.uint64):
    return jax.ShapeDtypeStruct(shape, dtype)
